"""Step factories: jit-able train / prefill / decode steps with shardings.

``make_train_step(bundle, ctx)`` returns ``step(state, batch) -> (state,
metrics)`` with:

* loss + grad under remat (``cfg.remat``),
* optional microbatching (gradient accumulation via ``lax.scan`` over
  microbatch slices — hillclimb lever for activation memory),
* optional gradient compression hook (``repro.parallel.compression``),
* AdamW update with cosine schedule.

``make_serve_steps`` returns (prefill_step, decode_step) — the static
serving pair.  ``make_slot_decode_step`` is the continuous-batching
variant: a slot-masked decode where every batch slot advances at its own
position (see repro.serve for the scheduler/KV-manager that drives it).

Checkpoint-commit planning (how many per-device shard pipelines flush the
state this step produces) lives with the commit scheduler:
``repro.dsm.flit_runtime.auto_shard_count`` sizes pipelines from the
actual HBM state volume; callers pass ``n_shards=None`` to get it.

All functions are pure; shardings are applied by the caller via
``jax.jit(..., in_shardings=..., out_shardings=...)`` (see launch/dryrun).
"""
from __future__ import annotations

from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.registry import ModelBundle
from repro.optim.adamw import adamw_update
from repro.optim.schedule import cosine_schedule
from repro.train.state import TrainState


def make_train_step(bundle: ModelBundle, ctx=None, *,
                    microbatch: int = 1,
                    peak_lr: float = 3e-4,
                    total_steps: int = 10_000,
                    grad_transform: Optional[Callable] = None,
                    moe_mode: str = "a2a",
                    donate: bool = True) -> Callable:
    """Build the train step. ``grad_transform(grads, ctx) -> grads`` is the
    gradient-compression hook (identity if None)."""
    cfg = bundle.cfg

    def loss_of(params, batch):
        loss, metrics = bundle.loss(params, batch, ctx=ctx,
                                    moe_mode=moe_mode, with_remat=True)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_of, has_aux=True)

    def accumulate(params, batch):
        if microbatch <= 1:
            (loss, metrics), grads = grad_fn(params, batch)
            return loss, metrics, grads
        B = batch["tokens"].shape[0]
        assert B % microbatch == 0, (B, microbatch)
        mb = B // microbatch

        def slice_mb(x, i):
            return jax.lax.dynamic_slice_in_dim(x, i * mb, mb, 0)

        def body(carry, i):
            loss_acc, grads_acc = carry
            mb_batch = jax.tree_util.tree_map(partial(slice_mb, i=i), batch)
            (loss, metrics), grads = grad_fn(params, mb_batch)
            grads_acc = jax.tree_util.tree_map(jnp.add, grads_acc, grads)
            return (loss_acc + loss, grads_acc), metrics

        zeros = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (loss_sum, grads), metrics = jax.lax.scan(
            body, (jnp.zeros((), jnp.float32), zeros),
            jnp.arange(microbatch))
        inv = 1.0 / microbatch
        grads = jax.tree_util.tree_map(lambda g: g * inv, grads)
        metrics = jax.tree_util.tree_map(lambda m: m[-1], metrics)
        return loss_sum * inv, metrics, grads

    # grads must land on the PARAM shardings before the optimizer update:
    # without the constraint GSPMD is free to all-reduce FSDP gradients to
    # full (replicated) size and run the fp32 moment math unsharded —
    # ~100 GB/device at jamba scale. The constraint forces reduce-scatter
    # + fully sharded optimizer math (ZeRO).
    grad_specs = None
    if ctx is not None and ctx.mesh is not None:
        from jax.sharding import NamedSharding
        from repro.parallel.sharding import param_specs
        grad_specs = jax.tree_util.tree_map(
            lambda s: NamedSharding(ctx.mesh, s),
            param_specs(ctx, bundle.descs))

    def step(state: TrainState, batch) -> Tuple[TrainState, Dict[str, Any]]:
        loss, metrics, grads = accumulate(state.params, batch)
        if grad_specs is not None:
            grads = jax.lax.with_sharding_constraint(grads, grad_specs)
        if grad_transform is not None:
            grads = grad_transform(grads, ctx)
        lr = cosine_schedule(state.opt.step, peak_lr=peak_lr,
                             total=total_steps)
        params, opt, gnorm = adamw_update(
            state.params, grads, state.opt, lr,
            weight_decay=0.1, grad_clip=1.0)
        new_state = TrainState(params=params, opt=opt, rng=state.rng)
        out = {"loss": loss, "lr": lr, "grad_norm": gnorm,
               "step": opt.step, **metrics}
        return new_state, out

    return step


def make_serve_steps(bundle: ModelBundle, ctx=None, *,
                     moe_mode_prefill: str = "a2a",
                     moe_mode_decode: str = "psum"):
    cfg = bundle.cfg

    def prefill_step(params, batch, caches):
        return bundle.prefill(params, batch, caches, ctx=ctx,
                              moe_mode=moe_mode_prefill)

    def decode_step(params, tokens, state):
        return bundle.decode(params, tokens, state, ctx=ctx,
                             moe_mode=moe_mode_decode)

    return prefill_step, decode_step


def cache_batch_axes(bundle: ModelBundle):
    """Per-leaf index of the BATCH axis in the decode-cache pytree.

    Layer-stacked groups prepend a ``(repeats,)`` dim to their cache
    leaves, so batch is axis 1 there and axis 0 on singleton groups — any
    slot-wise cache surgery (vmap, per-slot insert/extract) must be driven
    by the cache descriptors' logical axis names, not a fixed axis."""
    from repro.models.params import tree_map_descs
    return tree_map_descs(lambda d: d.logical.index("batch"),
                          bundle.cache_descs(1, 2))


def make_slot_decode_step(bundle: ModelBundle, ctx=None, *,
                          moe_mode: str = "psum"):
    """The continuous-batching decode step: every slot advances by one
    token at its OWN position.

    ``slot_decode(params, tokens, caches, pos, active)`` with

    * ``tokens`` (B, 1) int32 — last sampled token per slot,
    * ``caches`` — batched cache pytree (B on the per-leaf batch axis),
    * ``pos``    (B,) int32 — per-slot decode position,
    * ``active`` (B,) bool — slot occupancy mask,

    returns ``(next_tokens (B,), logits (B, V), caches, pos)``; greedy
    argmax is baked into the graph (the repo's only sampler).  Built as a
    per-slot ``vmap`` of the single-sequence decode, so each slot's
    computation is INDEPENDENT of what the other slots hold — outputs do
    not depend on slot assignment or batch composition, which is what
    makes crash-replay of a session bit-identical under a different
    interleaving.  Inactive slots still compute (masked lanes are the
    price of a fixed batch shape) but their position does not advance and
    their garbage is overwritten wholesale at the next admission.
    """
    assert not bundle.cfg.is_encdec, "slot decode is decoder-only"
    from repro.models.lm import ServeState
    axes = cache_batch_axes(bundle)
    tree_map = jax.tree_util.tree_map

    def slot_decode(params, tokens, caches, pos, active):
        def one(tok, cache, p):
            cache1 = tree_map(lambda x, a: jnp.expand_dims(x, a),
                              cache, axes)
            logits, st = bundle.decode(params, tok[None],
                                       ServeState(cache1, p), ctx=ctx,
                                       moe_mode=moe_mode)
            nc = tree_map(lambda x, a: jnp.squeeze(x, a), st.caches, axes)
            return logits[0], nc, st.pos

        logits, new_caches, new_pos = jax.vmap(
            one, in_axes=(0, axes, 0), out_axes=(0, axes, 0))(
            tokens, caches, pos)
        new_pos = jnp.where(active, new_pos, pos)
        next_tokens = jnp.argmax(logits, -1).astype(jnp.int32)
        return next_tokens, logits, new_caches, new_pos

    return slot_decode
