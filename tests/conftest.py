"""Shared fixtures and the ONE place the 8-device host platform is forced.

Several suites (elastic, MoE expert parallelism, parallel strategies,
mesh-native commit) need a real multi-device ``jax.Mesh``, which on CPU
hosts means ``--xla_force_host_platform_device_count=8``.  JAX pins the
device count at backend initialisation, so the flag must be in the
environment BEFORE anything imports jax — pytest imports this conftest
ahead of every test module, making it the single reliable hook.  Tests
that spawn subprocess workers inherit the flag through the environment;
an already-forced count (e.g. a CI job exporting its own XLA_FLAGS) is
left untouched.
"""
import os

if "xla_force_host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=8"
                               ).strip()

import pytest


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running exhaustive checks")


@pytest.fixture(scope="session")
def pallas_interpret() -> bool:
    """Platform-detected Pallas execution mode for kernel tests: compiled
    on a real accelerator backend, ``interpret=True`` on CPU hosts (same
    kernel body, run by the Pallas interpreter — numerics identical)."""
    from repro.kernels.compat import default_interpret
    return default_interpret()


@pytest.fixture(scope="session")
def host_devices_8():
    """The 8 forced host devices.  Skips (instead of mysteriously failing
    mesh construction) when a jax backend was already live before this
    conftest could force the count — e.g. pytest run from a process that
    imported jax first, or an environment pinning a smaller force."""
    import jax
    if jax.device_count() < 8:
        pytest.skip(
            "needs 8 host devices but the jax backend initialised with "
            f"{jax.device_count()} — conftest.py could not force "
            "--xla_force_host_platform_device_count=8 (backend already "
            "live or XLA_FLAGS pinned elsewhere)")
    return jax.devices()
