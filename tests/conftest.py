import pytest


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running exhaustive checks")


@pytest.fixture(scope="session")
def pallas_interpret() -> bool:
    """Platform-detected Pallas execution mode for kernel tests: compiled
    on a real accelerator backend, ``interpret=True`` on CPU hosts (same
    kernel body, run by the Pallas interpreter — numerics identical)."""
    from repro.kernels.compat import default_interpret
    return default_interpret()
