"""The unified programming-model API (repro.dsm.api): config round-trip,
export surface, and EQUIVALENCE — a run wired through `open_cxl0` /
commit regions must be bit-identical (pool manifests + recovered state)
to the legacy hand-wired five-object stack, including one crash/recovery
cell per subsystem (train / serve / cluster)."""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

import repro.dsm as dsm
from repro.dsm import (CXL0Config, CXL0Context, DSMPool, DurableCommitter,
                       RecoveryManager, TierManager, open_cxl0)
from repro.dsm.cluster import ClusterProtocol, FileStagingArea, rank_ns
from repro.dsm.recovery import ColdStartError, CrashError

REPO = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
SRC = os.path.join(REPO, "src")


# ---------------------------------------------------------------------------
# deterministic toy state (pure numpy — no jit, fast)
# ---------------------------------------------------------------------------

def init_objects():
    return {
        "params": {"w0": np.arange(12, dtype=np.float32).reshape(3, 4),
                   "w1": np.linspace(-1, 1, 8).astype(np.float32)},
        "opt": {"mu": np.zeros(6, np.float32),
                "nu": np.full(6, 0.5, np.float32)},
    }


def step_objects(objs, i):
    """Pure function of (state, step): both wirings replay identically."""
    import jax
    return jax.tree_util.tree_map(
        lambda a: a * np.float32(0.9) + np.float32(i + 1) / 16, objs)


def templates():
    import jax
    return jax.tree_util.tree_map(np.zeros_like, init_objects())


def manifest_docs(pool_dir):
    return DSMPool(pool_dir).manifests_desc()


def tree_equal(a, b):
    import jax
    la = jax.tree_util.tree_leaves(a)
    lb = jax.tree_util.tree_leaves(b)
    return len(la) == len(lb) and all(
        np.array_equal(np.asarray(x), np.asarray(y))
        for x, y in zip(la, lb))


# ---------------------------------------------------------------------------
# config + exports
# ---------------------------------------------------------------------------

def test_config_round_trip():
    cfg = CXL0Config(path="/x/pool", worker_id=3, topology="cxl30-fabric",
                     schedule="sharded", n_shards=4, retention=7)
    d = cfg.to_dict()
    assert json.loads(json.dumps(d)) == d       # JSON-serializable
    back = CXL0Config.from_dict(d)
    assert back.to_dict() == d
    assert (back.path, back.worker_id, back.topology, back.schedule,
            back.n_shards, back.retention) == \
        ("/x/pool", 3, "cxl30-fabric", "sharded", 4, 7)


def test_config_schedule_resolution():
    assert CXL0Config(path="p").resolved_schedule() == "sharded-async"
    assert CXL0Config(path="p", schedule="sync").resolved_schedule() == "sync"
    assert CXL0Config(path="p", topology="cxl11-direct") \
        .resolved_schedule() == "auto"
    with pytest.raises(ValueError):
        CXL0Config(path="p", schedule="bogus")


def test_config_open_wires_the_stack(tmp_path):
    ctx = CXL0Config(path=str(tmp_path / "p"), worker_id=2,
                     topology="cxl20-switched-pool", schedule="sync",
                     retention=3).open()
    assert isinstance(ctx, CXL0Context)
    assert ctx.committer.mode == "sync"
    assert ctx.committer.retention == 3
    assert ctx.tiers.worker_id == 2
    assert ctx.placement is not None
    assert ctx.placement.topology.name == "cxl20-switched-pool"
    assert ctx.committer.placement is ctx.placement
    ctx.close()


def test_all_exports():
    expected = {"open_cxl0", "CXL0Context", "CXL0Config", "CommitRegion",
                "DurableHandle", "TransformedObject", "DSMPool",
                "TierManager", "DurableCommitter", "RecoveryManager",
                "CrashError", "ColdStartError"}
    assert expected <= set(dsm.__all__)
    ns = {}
    exec("from repro.dsm import *", ns)
    assert expected <= set(ns)


def test_import_clean_under_deprecation_errors():
    """`import repro.dsm` must not trip -W error::DeprecationWarning."""
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env.setdefault("JAX_PLATFORMS", "cpu")
    proc = subprocess.run(
        [sys.executable, "-W", "error::DeprecationWarning", "-c",
         "import repro.dsm"],
        env=env, capture_output=True, text=True, timeout=300)
    assert proc.returncode == 0, proc.stderr[-2000:]


def test_py_typed_marker_ships():
    assert os.path.exists(os.path.join(SRC, "repro", "py.typed"))


def test_no_tiermanager_constructed_outside_dsm():
    """The acceptance grep as a test: every subsystem builds its stack via
    open_cxl0/CXL0Config — TierManager is constructed only inside
    repro/dsm (and tests)."""
    offenders = []
    for root in ("src", "examples", "benchmarks"):
        for dirpath, _, files in os.walk(os.path.join(REPO, root)):
            if os.path.join("repro", "dsm") in dirpath:
                continue
            for fn in files:
                if not fn.endswith(".py"):
                    continue
                p = os.path.join(dirpath, fn)
                with open(p) as f:
                    if "TierManager(" in f.read():
                        offenders.append(os.path.relpath(p, REPO))
    assert not offenders, offenders


# ---------------------------------------------------------------------------
# TRAIN: ctx-wired run == legacy hand-wired run, bit for bit
# ---------------------------------------------------------------------------

N_STEPS, CADENCE = 6, 2


def legacy_train(pool_dir, mode="sync", n_shards=None):
    """The pre-API wiring, verbatim: five objects assembled by hand."""
    pool = DSMPool(pool_dir)
    tiers = TierManager(pool, 0)
    committer = DurableCommitter(tiers, mode=mode, n_shards=n_shards)
    objs = init_objects()
    committer.update(objs, step=-1)
    committer.commit(-1)
    committer.drain()
    for i in range(N_STEPS):
        objs = step_objects(objs, i)
        committer.update(objs, step=i)
        if (i + 1) % CADENCE == 0:
            committer.commit(i)
    committer.drain()
    tiers.close()
    rec = RecoveryManager(pool).recover(templates(), ())
    return objs, rec


def ctx_train(pool_dir, mode="sync", n_shards=None):
    """The same program through the unified API."""
    ctx = open_cxl0(pool_dir, schedule=mode, n_shards=n_shards)
    objs = init_objects()
    ctx.put(objs, step=-1)
    with ctx.commit(-1):
        pass
    ctx.drain()
    for i in range(N_STEPS):
        objs = step_objects(objs, i)
        ctx.put(objs, step=i)
        if (i + 1) % CADENCE == 0:
            with ctx.commit(i):
                pass
    ctx.drain()
    ctx.close()
    rec = ctx.recover(templates())
    return objs, rec


@pytest.mark.parametrize("mode,n_shards", [("sync", None),
                                           ("sharded-async", 2)])
def test_train_equivalence_bit_identical(tmp_path, mode, n_shards):
    objs_l, rec_l = legacy_train(str(tmp_path / "legacy"), mode, n_shards)
    objs_c, rec_c = ctx_train(str(tmp_path / "ctx"), mode, n_shards)
    # identical manifest DOCUMENTS (seq, step, per-object version/crc/
    # nbytes, meta) — the durable history is bit-identical
    docs_l = manifest_docs(str(tmp_path / "legacy"))
    docs_c = manifest_docs(str(tmp_path / "ctx"))
    assert docs_l == docs_c
    assert len(docs_l) == 1 + N_STEPS // CADENCE
    # identical live and recovered state
    assert tree_equal(objs_l, objs_c)
    assert rec_l[1:] == rec_c[1:]               # (step, source)
    assert tree_equal(rec_l[0], rec_c[0])


@pytest.mark.parametrize("point", ["pre_flush", "post_completeOp"])
def test_train_crash_cell(tmp_path, point):
    """One crash/recovery cell through the migrated training entry point:
    a CrashError fired INSIDE the commit window at `point`; the loop must
    recover to a completed commit and end bit-identical to a clean run."""
    import jax
    from repro.data.pipeline import DataPipeline, SyntheticLMSource
    from repro.scenarios.worker import make_toy_state, make_toy_step
    from repro.train.loop import run_durable_loop

    def run(pool_dir, hook=None):
        pipe = DataPipeline(SyntheticLMSource(64), 2, 8)
        return run_durable_loop(
            make_toy_step(), make_toy_state(dim=8, n_tensors=2, seed=0),
            pipe, DSMPool(pool_dir), n_steps=6, commit_every=2,
            commit_mode="sync", fault_hook=hook)

    fired = []

    def hook(p, step):
        if not fired and p == point and step >= 3:
            fired.append(step)
            raise CrashError(f"injected at {p}")

    r = run(str(tmp_path / "crash"), hook)
    clean = run(str(tmp_path / "clean"))
    assert fired and r.crashes == 1
    assert r.recoveries == ["pool"]
    assert tree_equal(r.state.params, clean.state.params)
    # both pools end with the same durable history
    assert (manifest_docs(str(tmp_path / "crash"))[0]["step"]
            == manifest_docs(str(tmp_path / "clean"))[0]["step"])


# ---------------------------------------------------------------------------
# SERVE: SessionStore(ctx) == legacy hand-wired commit, bit for bit
# ---------------------------------------------------------------------------

def serve_caches(tick):
    return {
        "s1": {"k": np.arange(8, dtype=np.float32) + tick,
               "v": np.full(4, 2.0 + tick, np.float32)},
        "s2": {"k": np.arange(8, dtype=np.float32) * 2 + tick,
               "v": np.full(4, 7.0 + tick, np.float32)},
    }


def serve_table(store_like_versions, tick):
    from repro.serve.sessions import Session
    table = {}
    for rid in ("s1", "s2"):
        s = Session(rid, prompt=(1, 2, 3), max_new_tokens=4,
                    emitted=[9, 8][: 1 + tick % 2])
        s.cache_version = store_like_versions[rid]
        table[rid] = s
    return table


def test_serve_equivalence_bit_identical(tmp_path):
    from repro.serve.sessions import SessionStore, kv_name

    # -- legacy: hand-wired tiers + committer, meta assembled by hand ----
    pool_l = DSMPool(str(tmp_path / "legacy"))
    tiers = TierManager(pool_l, 0)
    committer = DurableCommitter(tiers, mode="sync", retention=2)
    for tick in (3, 7):
        caches = serve_caches(tick)
        versions = {}
        for rid, c in caches.items():
            tiers.lstore(kv_name(rid), c)
            versions[rid] = tiers.versions[kv_name(rid)]
        table = serve_table(versions, tick)
        meta = {"kind": "serve",
                "sessions": {rid: s.to_meta() for rid, s in table.items()}}
        committer.commit(tick, meta=meta)
    tiers.close()

    # -- new API: the migrated SessionStore over an open_cxl0 context ----
    store = SessionStore(DSMPool(str(tmp_path / "ctx")), mode="sync",
                         retention=2)
    for tick in (3, 7):
        caches = serve_caches(tick)
        versions = {}
        for rid, c in caches.items():
            store.tiers.lstore(kv_name(rid), c)
            versions[rid] = store.tiers.versions[kv_name(rid)]
        table = serve_table(versions, tick)
        store.commit(table, tick)
    store.close()

    docs_l = manifest_docs(str(tmp_path / "legacy"))
    docs_c = manifest_docs(str(tmp_path / "ctx"))
    assert docs_l == docs_c and len(docs_l) == 2

    # recovered state identical through the store's recovery path
    rec = SessionStore(DSMPool(str(tmp_path / "ctx"))).recover(
        {"k": np.zeros(8, np.float32), "v": np.zeros(4, np.float32)})
    assert rec is not None and rec.step == 7
    assert tree_equal(rec.caches["s1"], serve_caches(7)["s1"])


def test_serve_crash_cell(tmp_path):
    """Crash inside the session-commit window (pre_flush): no completeOp,
    a restarted store recovers the PREVIOUS committed tick."""
    from repro.serve.sessions import SessionStore, kv_name

    def hook(point, step):
        if point == "pre_flush" and step >= 7:
            raise CrashError("die in the commit window")

    store = SessionStore(DSMPool(str(tmp_path)), mode="sync",
                         fault_hook=hook)
    committed = {}
    for tick in (3, 7):
        caches = serve_caches(tick)
        versions = {}
        for rid, c in caches.items():
            store.tiers.lstore(kv_name(rid), c)
            versions[rid] = store.tiers.versions[kv_name(rid)]
        table = serve_table(versions, tick)
        if tick == 3:
            store.commit(table, tick)
            committed = caches
        else:
            with pytest.raises(CrashError):
                store.commit(table, tick)
    store.ctx.crash()

    restarted = SessionStore(DSMPool(str(tmp_path)))
    rec = restarted.recover({"k": np.zeros(8, np.float32),
                             "v": np.zeros(4, np.float32)})
    assert rec is not None and rec.step == 3        # previous commit
    assert tree_equal(rec.caches["s1"], committed["s1"])


# ---------------------------------------------------------------------------
# CLUSTER: delegated completeOp + the one recovery path
# ---------------------------------------------------------------------------

def cluster_objects(step):
    return {rank_ns(0, "params"): {"t": np.arange(6, dtype=np.float32)
                                   + step},
            rank_ns(0, "opt"): {"t": np.full(6, 0.25 + step, np.float32)}}


def test_cluster_equivalence_bit_identical(tmp_path):
    """A rank committing through the elected cluster protocol: legacy
    hand-wired committer(complete_fn=...) vs open_cxl0(complete_fn=...)
    produce bit-identical cluster manifests."""
    def run(pool_dir, use_ctx):
        pool = DSMPool(pool_dir)
        proto = ClusterProtocol(pool, 0, [0])
        if use_ctx:
            ctx = open_cxl0(pool, 0, schedule="sharded", n_shards=2,
                            complete_fn=proto.cluster_complete)
            for step in range(4):
                ctx.put(cluster_objects(step), step=step)
                if step % 2 == 1:
                    with ctx.commit(step, meta={"live": [0]}):
                        pass
            ctx.close()
        else:
            tiers = TierManager(pool, 0)
            committer = DurableCommitter(
                tiers, mode="sharded", n_shards=2,
                complete_fn=proto.cluster_complete)
            for step in range(4):
                committer.update(cluster_objects(step), step=step)
                if step % 2 == 1:
                    committer.commit(step, meta={"live": [0]})
            tiers.close()

    run(str(tmp_path / "legacy"), use_ctx=False)
    run(str(tmp_path / "ctx"), use_ctx=True)
    docs_l = manifest_docs(str(tmp_path / "legacy"))
    docs_c = manifest_docs(str(tmp_path / "ctx"))
    assert docs_l == docs_c and len(docs_l) == 2
    assert set(docs_l[0]["objects"]) == set(cluster_objects(0))
    assert docs_l[0]["meta"] == {"live": [0]}   # the elected commit's meta


def test_cluster_crash_cell_staging_precedence(tmp_path):
    """The crash/recovery cell of the cluster subsystem: a victim's
    partition recovered by its sibling — ctx.recover must adopt the
    cross-process RStore-staged copy when its tag beats the newest
    cluster manifest and fall back to the pool when it doesn't,
    bit-identical to the legacy RecoveryManager path."""
    pool_dir = str(tmp_path / "pool")
    area = FileStagingArea(str(tmp_path / "staging"))
    name = rank_ns(0, "params")
    old = {"t": np.zeros(4, np.float32)}
    new = {"t": np.full(4, 2.5, np.float32)}

    victim = open_cxl0(pool_dir, 0)
    h = victim.durable(name, init=old)
    victim.pool.commit_manifest(3, {name: h.rflush()})   # pool at step 3
    h.lstore(new)
    h.rstore(area.proxy(1), tag=5)                       # staged at step 5
    victim.crash()

    # sibling adopts: fresh handles, as a separate process would have
    sibling = open_cxl0(pool_dir, 1)
    view = FileStagingArea(str(tmp_path / "staging")).view(1, {name: old})
    objs, step, source = sibling.recover({name: old}, peers=(view,),
                                         exact=False)
    legacy = RecoveryManager(DSMPool(pool_dir)).recover(
        {name: old}, peers=(view,), exact=False)
    assert (step, source) == (5, "peer-staging") == legacy[1:]
    assert tree_equal(objs, legacy[0])
    assert np.array_equal(np.asarray(objs[name]["t"]), new["t"])

    # stale staging (tag <= newest manifest step) loses to the pool
    h2 = open_cxl0(pool_dir, 0).durable(name, init=old)
    area.proxy(1).staging[name] = (3, {"t": np.asarray(old["t"])})
    view = area.view(1, {name: old})
    objs, step, source = sibling.recover({name: old}, peers=(view,),
                                         exact=False)
    assert (step, source) == (3, "pool")


# ---------------------------------------------------------------------------
# commit regions, handles, §6 transform
# ---------------------------------------------------------------------------

def test_commit_region_crash_inside_emits_no_completeop(tmp_path):
    ctx = open_cxl0(str(tmp_path), schedule="sync")
    with ctx.commit(0) as txn:
        txn.store("x", {"a": np.arange(3, dtype=np.float32)})
    with pytest.raises(RuntimeError):
        with ctx.commit(1) as txn:
            txn.store("x", {"a": np.full(3, 9.0, np.float32)})
            raise RuntimeError("crash inside the region")
    docs = manifest_docs(str(tmp_path))
    assert [d["step"] for d in docs] == [0]     # step 1 never completed
    objs, step, source = ctx.recover({"x": {"a": np.zeros(3, np.float32)}})
    assert step == 0 and source == "pool"
    assert np.array_equal(np.asarray(objs["x"]["a"]),
                          np.arange(3, dtype=np.float32))


def test_commit_region_rollback_keeps_later_commits_clean(tmp_path):
    """A caller that CATCHES the exception in-process and keeps committing
    must not have the torn batch published by a later commit: the region
    rolls its own stores back out of the volatile tier."""
    ctx = open_cxl0(str(tmp_path), schedule="sync")
    with ctx.commit(0) as txn:
        txn.store("a", {"v": np.full(2, 1.0, np.float32)})
    with pytest.raises(RuntimeError):
        with ctx.commit(1) as txn:
            txn.store("a", {"v": np.full(2, 9.0, np.float32)})
            txn.store("b", {"v": np.full(2, 5.0, np.float32)})   # brand new
            raise RuntimeError("crash inside the region")
    with ctx.commit(2):                         # commits whatever is live
        pass
    doc = manifest_docs(str(tmp_path))[0]
    assert doc["step"] == 2
    assert set(doc["objects"]) == {"a"}         # "b" never leaked
    objs, _, _ = ctx.recover({"a": {"v": np.zeros(2, np.float32)}})
    assert np.array_equal(np.asarray(objs["a"]["v"]), np.full(2, 1.0))


def test_commit_region_reports_stats(tmp_path):
    ctx = open_cxl0(str(tmp_path), schedule="sync")
    with ctx.commit(4, meta={"tag": "t"}) as txn:
        txn.store("x", {"a": np.ones(2, np.float32)})
    assert txn.stats is not None
    assert txn.stats.step == 4 and txn.stats.n_objects == 1
    assert manifest_docs(str(tmp_path))[0]["meta"] == {"tag": "t"}


def test_durable_handle_primitives(tmp_path):
    ctx = open_cxl0(str(tmp_path / "a"), schedule="sync")
    peer = open_cxl0(str(tmp_path / "b"), 1)
    h = ctx.durable("obj", init={"v": np.zeros(2, np.float32)})
    assert h.version == 1
    obj = h.mstore({"v": np.full(2, 3.0, np.float32)})
    assert (obj.version, h.version) == (2, 2)
    assert np.array_equal(np.asarray(h.value["v"]), np.full(2, 3.0))
    h.rstore(peer, tag=7)                       # a context IS a peer
    assert "obj" in peer.staging
    with pytest.raises(ValueError):
        ctx.durable("other", init={"v": np.zeros(1, np.float32)}).rstore()


def test_transform_survives_crash(tmp_path):
    from repro.core.objects import CounterSpec
    ctx = open_cxl0(str(tmp_path), schedule="sync")
    ctr = ctx.transform(CounterSpec(), name="ctr")
    assert [ctr.op("inc") for _ in range(5)] == [0, 1, 2, 3, 4]
    ctx.crash()
    revived = open_cxl0(str(tmp_path)).transform(CounterSpec(), name="ctr")
    assert revived.state == 5 and revived.ops_done == 4
    assert revived.recovered_from == (4, "pool")
    assert revived.op("inc") == 5               # history continues


def test_transform_tuple_states_round_trip(tmp_path):
    from repro.core.objects import StackSpec
    ctx = open_cxl0(str(tmp_path), schedule="sync")
    st = ctx.transform(StackSpec(), name="stack")
    st.op("push", 7)
    st.op("push", 9)
    ctx.crash()
    revived = open_cxl0(str(tmp_path)).transform(StackSpec(), name="stack")
    assert revived.state == (7, 9)              # tuples, not JSON lists
    assert revived.op("pop") == 9


def test_try_recover_cold_pool(tmp_path):
    ctx = open_cxl0(str(tmp_path))
    assert ctx.try_recover({"x": np.zeros(1, np.float32)}) is None
    with pytest.raises(ColdStartError):
        ctx.recover({"x": np.zeros(1, np.float32)})
