"""Per-architecture smoke tests (assignment requirement): reduced config of
the same family, one forward/train step on CPU, shape + finiteness asserts;
plus prefill/decode-vs-full-forward consistency and a parameter-count check
of the FULL config against published totals (descriptors only — no
allocation)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import (
    ARCH_IDS, PUBLISHED_PARAMS, get_config, get_smoke_config,
)
from repro.models.registry import build, input_specs
from repro.configs.base import SHAPES_BY_NAME


def _batch(cfg, key, B, S):
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
    if cfg.is_encdec:
        batch["enc_embeds"] = jax.random.normal(
            key, (B, cfg.encdec.enc_seq, cfg.d_model), jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_smoke(arch):
    cfg = get_smoke_config(arch)
    b = build(cfg, dec_pos_len=64)
    key = jax.random.PRNGKey(0)
    params = b.init_params(key)
    batch = _batch(cfg, key, B=2, S=32)

    def step(p, bt):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: b.loss(p, bt), has_aux=True)(p)
        return loss, metrics, grads

    loss, metrics, grads = jax.jit(step)(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), arch
    # gradients exist, are finite, and match parameter shapes
    flat, _ = jax.tree_util.tree_flatten(grads)
    pflat, _ = jax.tree_util.tree_flatten(params)
    assert len(flat) == len(pflat)
    for g, p in zip(flat, pflat):
        assert g.shape == p.shape
        assert bool(jnp.all(jnp.isfinite(g.astype(jnp.float32)))), arch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_consistency(arch):
    """prefill(S) + decode(1) must agree with full forward on S+1 tokens."""
    cfg = get_smoke_config(arch)
    b = build(cfg, dec_pos_len=64)
    key = jax.random.PRNGKey(1)
    params = b.init_params(key)
    B, S, T_MAX = 2, 16, 32
    toks = jax.random.randint(key, (B, S + 1), 0, cfg.vocab_size)
    batch = _batch(cfg, key, B, S)
    batch["tokens"] = toks[:, :S]
    caches = b.init_caches(key, B, T_MAX)
    logits_p, state = jax.jit(lambda p, bt, c: b.prefill(p, bt, c))(
        params, batch, caches)
    logits_d, _ = jax.jit(lambda p, t, s: b.decode(p, t, s))(
        params, toks[:, S:S + 1], state)

    if cfg.is_encdec:
        from repro.models import encdec, common
        enc_out = encdec.encode(cfg, params, batch["enc_embeds"])
        x, _ = encdec.decode_tokens(cfg, params, toks, enc_out)
        ref = common.unembed(cfg, params["embed"], x).astype(jnp.float32)
    else:
        from repro.models import lm
        ref, _ = lm.forward(cfg, params, toks)
        ref = ref.astype(jnp.float32)

    # bf16 tolerance; MLA absorbed decode reorders matmuls
    assert jnp.max(jnp.abs(logits_p.astype(jnp.float32) - ref[:, S - 1])) < 0.05
    assert jnp.max(jnp.abs(logits_d.astype(jnp.float32) - ref[:, S])) < 0.05
    assert bool((jnp.argmax(logits_d, -1) == jnp.argmax(ref[:, S], -1)).all())


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_count_matches_published(arch):
    cfg = get_config(arch)
    n = build(cfg, dec_pos_len=448).n_params()
    pub = PUBLISHED_PARAMS[arch]
    assert abs(n - pub) / pub < 0.04, (
        f"{arch}: {n/1e9:.2f}B vs published {pub/1e9:.2f}B")


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_input_specs_all_shapes(arch):
    cfg = get_config(arch)
    for name, shape in SHAPES_BY_NAME.items():
        specs = input_specs(cfg, shape)
        assert "tokens" in specs
        if shape.kind == "decode":
            assert specs["tokens"].shape == (shape.global_batch, 1)
        else:
            assert specs["tokens"].shape == (shape.global_batch,
                                             shape.seq_len)


def test_layer_groups_cover_all_layers():
    from repro.models.lm import layer_groups, layer_kinds
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        if cfg.is_encdec:
            continue
        groups = layer_groups(cfg)
        reconstructed = []
        for g in groups:
            for _ in range(g.n_repeats):
                reconstructed.extend(g.kinds)
        assert reconstructed == layer_kinds(cfg), arch
        # the decomposition must be compact (small HLO): few groups
        assert len(groups) <= 3, (arch, len(groups))


def test_jamba_grouping_period8():
    cfg = get_config("jamba-1.5-large-398b")
    from repro.models.lm import layer_groups
    (g,) = layer_groups(cfg)
    assert len(g.kinds) == 8 and g.n_repeats == 9
    assert g.kinds[4][0] == "attn"                     # l % 8 == 4
    assert sum(k[0] == "attn" for k in g.kinds) == 1   # 1:7 interleave
    assert sum(k[1] == "moe" for k in g.kinds) == 4    # every other layer


def test_deepseek_grouping_first_dense():
    cfg = get_config("deepseek-v2-236b")
    from repro.models.lm import layer_groups
    gs = layer_groups(cfg)
    assert gs[0].kinds == (("attn", "dense"),) and gs[0].n_repeats == 1
    assert gs[1].kinds == (("attn", "moe"),) and gs[1].n_repeats == 59
