"""The perf-regression gate (scripts/bench_gate.py) + the shared bench
harness (benchmarks/harness.py): the gate MUST exit nonzero on a
synthetically regressed BENCH json (the CI contract), pass on matching
output, and treat missing metrics/files as regressions.  The committed
baselines themselves are validated for schema."""
import glob
import json
import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, REPO)                 # scripts/ + benchmarks/ packages

from benchmarks.harness import Bench                        # noqa: E402
from scripts.bench_gate import check_metric, gate_bench, main  # noqa: E402


# ---------------------------------------------------------------------------
# harness
# ---------------------------------------------------------------------------

def test_harness_writes_bench_json(tmp_path, capsys):
    b = Bench("demo")
    b.set_config(n=3)
    b.record("m_float", 1.25, "a note", fmt=".1f")
    b.record("m_int", 7)
    b.record("m_bool", True, "flag")
    b.record("family", 1.0, "mode=x", key="family.x")
    b.record("family", 2.0, "mode=y", key="family.y")
    path = b.write(str(tmp_path))
    out = capsys.readouterr().out
    assert "m_float,1.2,a note" in out          # CSV format kept (fmt)
    assert "family,2.0,mode=y" in out
    doc = json.load(open(path))
    assert doc["bench"] == "demo"
    assert doc["config"] == {"n": 3}
    assert doc["metrics"]["m_float"]["value"] == 1.25   # raw, not formatted
    assert doc["metrics"]["family.x"]["value"] == 1.0
    assert doc["metrics"]["family.y"]["value"] == 2.0
    assert doc["metrics"]["m_bool"]["value"] is True


def test_harness_collisions_never_overwrite(tmp_path):
    b = Bench("demo")
    b.record("m", 1)
    b.record("m", 2)
    b.record("m", 3)
    assert [b.metrics[k]["value"] for k in ("m", "m#2", "m#3")] == [1, 2, 3]


# ---------------------------------------------------------------------------
# per-metric comparison
# ---------------------------------------------------------------------------

def test_check_metric_directions():
    higher = {"value": 10.0, "direction": "higher", "rel_tol": 0.1}
    assert check_metric("k", higher, 9.5) is None        # inside tolerance
    assert check_metric("k", higher, 20.0) is None       # improvement
    assert check_metric("k", higher, 8.0) is not None    # regression
    lower = {"value": 10.0, "direction": "lower", "abs_tol": 1.0}
    assert check_metric("k", lower, 10.9) is None
    assert check_metric("k", lower, 12.0) is not None
    exact = {"value": 4, "direction": "exact"}
    assert check_metric("k", exact, 4) is None
    assert check_metric("k", exact, 5) is not None


def test_check_metric_bool_and_string():
    assert check_metric("k", {"value": True}, True) is None
    assert check_metric("k", {"value": True}, False) is not None
    assert check_metric("k", {"value": "9s/15p"}, "9s/15p") is None
    assert check_metric("k", {"value": "9s/15p"}, "8s/16p") is not None


# ---------------------------------------------------------------------------
# the gate end to end
# ---------------------------------------------------------------------------

def _setup(tmp_path, actual_value):
    bdir = tmp_path / "baselines"
    bdir.mkdir()
    (bdir / "demo.json").write_text(json.dumps({
        "bench": "demo",
        "metrics": {"speed": {"value": 100.0, "direction": "higher",
                              "rel_tol": 0.05}}}))
    b = Bench("demo")
    b.record("speed", actual_value)
    b.write(str(tmp_path))
    return bdir


def test_gate_passes_on_healthy_output(tmp_path):
    bdir = _setup(tmp_path, 99.0)        # within 5%
    assert main(["--baselines", str(bdir),
                 "--bench-dir", str(tmp_path)]) == 0


def test_gate_fails_on_synthetic_regression(tmp_path):
    bdir = _setup(tmp_path, 80.0)        # 20% below baseline
    assert main(["--baselines", str(bdir),
                 "--bench-dir", str(tmp_path)]) == 1


def test_gate_fails_on_missing_metric_and_missing_file(tmp_path):
    bdir = _setup(tmp_path, 99.0)
    # gated metric deleted from the bench output
    out = tmp_path / "BENCH_demo.json"
    doc = json.loads(out.read_text())
    doc["metrics"] = {}
    out.write_text(json.dumps(doc))
    assert main(["--baselines", str(bdir),
                 "--bench-dir", str(tmp_path)]) == 1
    # bench output missing entirely
    out.unlink()
    name, failures = gate_bench(str(bdir / "demo.json"), str(tmp_path))
    assert name == "demo" and failures
    assert main(["--baselines", str(bdir),
                 "--bench-dir", str(tmp_path)]) == 1


def test_gate_fails_with_no_baselines(tmp_path):
    (tmp_path / "empty").mkdir()
    assert main(["--baselines", str(tmp_path / "empty"),
                 "--bench-dir", str(tmp_path)]) == 1


def test_gate_cli_exit_status(tmp_path):
    """The CI contract is the PROCESS exit code: run the real script."""
    bdir = _setup(tmp_path, 50.0)        # regressed
    r = subprocess.run(
        [sys.executable, os.path.join(REPO, "scripts", "bench_gate.py"),
         "--baselines", str(bdir), "--bench-dir", str(tmp_path)],
        capture_output=True, text=True)
    assert r.returncode == 1
    assert "FAIL demo" in r.stdout


# ---------------------------------------------------------------------------
# committed baselines: schema sanity
# ---------------------------------------------------------------------------

def test_committed_baselines_schema():
    paths = glob.glob(os.path.join(REPO, "benchmarks", "baselines",
                                   "*.json"))
    assert paths, "no committed baselines"
    names = set()
    for p in paths:
        doc = json.load(open(p))
        assert doc["bench"], p
        names.add(doc["bench"])
        for key, spec in doc.get("metrics", {}).items():
            assert "value" in spec, (p, key)
            assert spec.get("direction", "exact") in (
                "higher", "lower", "exact"), (p, key)
    # every bench module run.py sweeps has a baseline (even if empty, the
    # gate then requires its BENCH json to exist)
    assert {"latency", "table1", "flit", "model_fuzz", "placement",
            "cluster", "checkpoint", "serve"} <= names
