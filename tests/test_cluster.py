"""Cluster protocol units (repro.dsm.cluster) + one end-to-end kill
scenario: cross-process staging feeds RecoveryManager's peer path,
rank records elect exactly one cluster completeOp per step, the
all-reduce board is bit-exact and doubles as the failure detector, and
killing 1 of 3 real worker processes mid-commit ends bit-identical to a
planned shrink."""
import os
import threading

import numpy as np
import jax.numpy as jnp
import pytest

from repro.dsm.cluster import (ClusterProtocol, ControlPlane,
                               FileStagingArea, MembershipChange,
                               ScalarReduceBoard, rank_ns, ring_sibling)
from repro.dsm.pool import DSMPool
from repro.dsm.recovery import RecoveryManager
from repro.dsm.tiers import TierManager
from repro.train.elastic import partition_plan


def test_partition_plan_covers_and_reassigns():
    names = [f"t{i}" for i in range(7)]
    plan = partition_plan(names, [0, 1, 2])
    assert set(plan) == set(names)
    assert set(plan.values()) <= {0, 1, 2}
    # every process derives the identical plan from the same membership
    assert plan == partition_plan(list(reversed(names)), [2, 0, 1])
    shrunk = partition_plan(names, [0, 2])
    assert set(shrunk.values()) <= {0, 2}     # victim's entries reassigned


def test_ring_sibling():
    assert ring_sibling(0, [0, 1, 2]) == 1
    assert ring_sibling(2, [0, 1, 2]) == 0
    assert ring_sibling(0, [0, 2]) == 2


def test_staging_roundtrip_and_wipe(tmp_path):
    area = FileStagingArea(str(tmp_path))
    tree = {"a": np.arange(6, dtype=np.float32).reshape(2, 3),
            "b": {"c": jnp.ones((3,), jnp.bfloat16)}}
    area.proxy(2).staging["w0/params"] = (7, tree)
    view = area.view(2, {"w0/params": tree})
    tag, back = view.staging["w0/params"]
    assert tag == 7
    assert np.array_equal(np.asarray(back["a"]), np.asarray(tree["a"]))
    assert str(np.asarray(back["b"]["c"]).dtype) == "bfloat16"
    assert np.asarray(back["b"]["c"]).tobytes() == \
        np.asarray(tree["b"]["c"]).tobytes()
    area.wipe(2)                  # the buffer owner's crash loses it
    assert view.staging and not area.view(2, {"w0/params": tree}).staging


def test_staging_meta_payload_mismatch_discarded(tmp_path):
    """A stager dying between the payload and meta renames leaves the OLD
    meta next to a NEW payload; the CRC recorded in the meta catches the
    mismatch and the copy is discarded (recovery falls back to the pool)
    instead of being adopted under the stale step tag."""
    area = FileStagingArea(str(tmp_path))
    old = {"a": np.zeros((3,), np.float32)}
    new = {"a": np.ones((3,), np.float32)}
    area.proxy(1).staging["w0/params"] = (1, old)
    meta_path = os.path.join(area.area(1), "w0__params.json")
    with open(meta_path) as f:
        stale_meta = f.read()
    area.proxy(1).staging["w0/params"] = (2, new)
    with open(meta_path, "w") as f:
        f.write(stale_meta)             # old meta now describes new payload
    assert not area.view(1, {"w0/params": old}).staging


def test_rstore_through_proxy_feeds_cross_process_recovery(tmp_path):
    """The tentpole wiring: TierManager.rstore targets a StagingProxy, a
    DIFFERENT 'process' (fresh objects, same dirs) reads the staged copy
    back through FileStagingArea.view, and RecoveryManager adopts it over
    an older pool manifest — the peer-staging path across processes."""
    pool = DSMPool(str(tmp_path / "pool"))
    area = FileStagingArea(str(tmp_path / "staging"))
    name = rank_ns(0, "params")
    tiers = TierManager(pool, worker_id=0)
    old = {"t": np.zeros((4,), np.float32)}
    new = {"t": np.full((4,), 2.5, np.float32)}
    tiers.lstore(name, old)
    pool.commit_manifest(3, {name: tiers.rflush(name)})   # pool at step 3
    tiers.lstore(name, new)
    tiers.rstore(name, area.proxy(1), tag=5)              # staged at step 5
    # --- sibling side: fresh handles, as a separate process would have ---
    view = FileStagingArea(str(tmp_path / "staging")).view(
        1, {name: {"t": np.zeros((4,), np.float32)}})
    objs, step, source = RecoveryManager(
        DSMPool(str(tmp_path / "pool"))).recover(
        {name: {"t": np.zeros((4,), np.float32)}}, peers=(view,),
        exact=False)
    assert (step, source) == (5, "peer-staging")
    assert np.array_equal(np.asarray(objs[name]["t"]), new["t"])
    # stale staging (tag <= pool step) loses to the pool
    tiers.rstore(name, area.proxy(1), tag=3)
    view = area.view(1, {name: old})
    objs, step, source = RecoveryManager(pool).recover(
        {name: old}, peers=(view,), exact=False)
    assert (step, source) == (3, "pool")
    assert np.array_equal(np.asarray(objs[name]["t"]), old["t"])


def test_subset_recovery_from_cluster_manifest(tmp_path):
    """exact=False: recover ONE rank's objects out of a manifest that
    references every rank's."""
    pool = DSMPool(str(tmp_path))
    tiers = TierManager(pool, worker_id=0)
    objs = {}
    for r in range(3):
        name = rank_ns(r, "params")
        tiers.lstore(name, {"t": np.full((2,), float(r), np.float32)})
        objs[name] = tiers.rflush(name)
    pool.commit_manifest(4, objs)
    tpl = {rank_ns(1, "params"): {"t": np.zeros((2,), np.float32)}}
    got = RecoveryManager(pool).recover_from_pool(tpl, exact=False)
    assert got is not None and got[1] == 4
    assert np.array_equal(np.asarray(got[0][rank_ns(1, "params")]["t"]),
                          np.full((2,), 1.0))
    # exact mode still refuses the superset manifest
    assert RecoveryManager(pool).recover_from_pool(tpl) is None


def test_reduce_board_bit_exact_and_detects_death(tmp_path):
    board = ScalarReduceBoard(str(tmp_path / "reduce"))
    control = ControlPlane(str(tmp_path / "control"))
    vals = {0: 0.1, 1: 2.30000000007, 2: -1.25}
    for r, v in vals.items():
        board.contribute(0, 5, r, v)
    total = board.combine(0, 5, [0, 1, 2], control=control)
    assert total == ((vals[0] + vals[1]) + vals[2])    # fixed order
    # generations never leak into each other
    with pytest.raises(TimeoutError):
        board.combine(1, 5, [0, 1, 2], timeout=0.2)
    # a posted death surfaces as MembershipChange while blocked
    board.contribute(0, 6, 0, 1.0)
    control.post(1)
    with pytest.raises(MembershipChange):
        board.combine(0, 6, [0, 1], control=control, timeout=5.0)


def test_cluster_commit_elects_exactly_one_manifest(tmp_path):
    """Three rank handles record step 2 concurrently: all records land in
    ONE cluster manifest, and only one completeOp happens even when every
    rank sees the full record set."""
    pool_dir = str(tmp_path)
    protos = [ClusterProtocol(DSMPool(pool_dir), r, [0, 1, 2])
              for r in range(3)]
    entries = {}
    for r, proto in enumerate(protos):
        tiers = TierManager(proto.pool, worker_id=r)
        name = rank_ns(r, "state")
        tiers.lstore(name, {"t": np.full((2,), float(r), np.float32)})
        entries[r] = {name: proto.pool.write_object(
            name, 1, {"t": np.full((2,), float(r), np.float32)})}
    barrier = threading.Barrier(3)
    seqs = [None] * 3

    def commit(r):
        protos[r].write_record(2, {n: dict(name=o.name, version=o.version,
                                           crc=o.crc, nbytes=o.nbytes)
                                   for n, o in entries[r].items()})
        barrier.wait()            # all records down -> all try to commit
        seqs[r] = protos[r].try_commit(2)

    threads = [threading.Thread(target=commit, args=(r,)) for r in range(3)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    winners = [s for s in seqs if s != -1]
    assert len(winners) == 1      # the O_EXCL marker elects exactly one
    ms = DSMPool(pool_dir).manifests_desc()
    assert len(ms) == 1 and ms[0]["step"] == 2
    assert set(ms[0]["objects"]) == {rank_ns(r, "state") for r in range(3)}


def test_commit_marker_failover(tmp_path):
    """A winner that dies between winning the .commit marker and renaming
    the manifest must not wedge the step forever: a waiter whose record
    set is complete takes over after the grace period (the duplicate-
    commit worst case is benign — same records, atomic seq)."""
    pool = DSMPool(str(tmp_path))
    protos = [ClusterProtocol(pool, r, [0, 1], timeout=8.0)
              for r in range(2)]
    for r, proto in enumerate(protos):
        obj = pool.write_object(rank_ns(r, "state"), 1,
                                {"t": np.zeros(2, np.float32)})
        proto.write_record(0, {obj.name: dict(
            name=obj.name, version=obj.version, crc=obj.crc,
            nbytes=obj.nbytes)})
    assert protos[0]._win_commit_marker(0)    # winner "dies" right here
    assert protos[1].try_commit(0) == -1      # wedged under the marker...
    m = protos[1].wait_manifest(0)            # ...until takeover kicks in
    assert m["step"] == 0
    assert set(m["objects"]) == {rank_ns(0, "state"), rank_ns(1, "state")}


def test_cluster_commit_waits_for_all_records(tmp_path):
    proto = ClusterProtocol(DSMPool(str(tmp_path)), 0, [0, 1])
    proto.write_record(0, {"w0/state": {"name": "w0/state", "version": 1,
                                        "crc": 0, "nbytes": 8}})
    assert proto.try_commit(0) == -1          # rank 1 not recorded yet
    assert proto.find_manifest(0) is None


@pytest.mark.parametrize("point", ["pre_flush", "mid_flush",
                                   "post_completeOp"])
@pytest.mark.parametrize("replicate", [True, False])
def test_kill_matrix_cell_via_fuzzer_corpus(tmp_path, point, replicate):
    """The legacy 6-cell kill matrix (3 commit-window points x replicate
    on/off) as pinned fault schedules of the adversarial fuzzer: rank 1
    dies at ``point`` of the second commit, and the episode's oracle must
    agree with the hand-derived ``expected_recovery`` table — a
    post-completeOp kill resumes from the just-durable pool manifest,
    earlier points from peer staging iff replication is on, else from
    the previous commit."""
    from repro.scenarios.cluster import expected_recovery
    from repro.scenarios.fuzz import corpus_cluster_cell
    kill_step, commit_every = 3, 2
    res = corpus_cluster_cell(point, replicate, str(tmp_path),
                              commit_every=commit_every,
                              kill_step=kill_step)
    assert res.ok, res.violations
    assert len(res.kills_fired) == 1
    assert res.kills_fired[0]["worker"] == 1
    rec = res.recoveries[0]
    assert "victim" in rec and rec["victim"] == 1
    assert (rec["step"], rec["source"]) == expected_recovery(
        point, replicate, kill_step, commit_every)


@pytest.mark.slow
def test_kill_one_of_three_matches_planned_shrink(tmp_path):
    """End-to-end (real processes): kill rank 1 of 3 at pre_flush; the
    survivors adopt the victim's partition from cross-process peer
    staging and finish bit-identical to a planned shrink.  The full
    matrix runs in the scenario suite (runner --suite cluster)."""
    from repro.scenarios.cluster import run_cluster_scenario
    res = run_cluster_scenario("pre_flush", str(tmp_path), replicate=True,
                               steps=8, commit_every=2)
    assert res.killed, res.detail
    assert res.recovery_source == "peer-staging", res
    assert res.resumed_from == res.expected_resume, res
    assert res.digests and res.digests == res.reference_digests, res
    assert res.ok
