"""Gradient compression: quantization error bounds + error feedback
unbiasedness + end-to-end training convergence with compression on."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis", reason="property-based tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.parallel.compression import (
    int8_roundtrip, make_int8_transform, make_topk_transform, topk_roundtrip,
)


def test_int8_error_bound():
    key = jax.random.PRNGKey(0)
    g = jax.random.normal(key, (128, 64)) * 3.0
    deq = int8_roundtrip(g)
    # max error <= scale/2 = max|g|/254
    assert float(jnp.max(jnp.abs(deq - g))) <= float(
        jnp.max(jnp.abs(g))) / 254 + 1e-6


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 2**31 - 1), st.floats(0.01, 100.0))
def test_int8_roundtrip_properties(seed, scale):
    g = jax.random.normal(jax.random.PRNGKey(seed), (64,)) * scale
    deq = int8_roundtrip(g)
    assert deq.shape == g.shape
    assert bool(jnp.all(jnp.isfinite(deq)))
    # signs preserved for entries well above the quantization step
    step = float(jnp.max(jnp.abs(g))) / 127
    big = jnp.abs(g) > step
    assert bool(jnp.all(jnp.sign(deq)[big] == jnp.sign(g)[big]))


def test_error_feedback_accumulates_to_truth():
    """Sum of compressed grads + final residual == sum of true grads."""
    transform, init_err = make_int8_transform()
    key = jax.random.PRNGKey(1)
    grads_seq = [jax.random.normal(jax.random.fold_in(key, i), (32,))
                 for i in range(20)]
    params = {"w": jnp.zeros((32,))}
    err = init_err(params)
    total_sent = jnp.zeros((32,))
    for g in grads_seq:
        sent, err = transform({"w": g}, err)
        total_sent = total_sent + sent["w"]
    truth = sum(grads_seq)
    resid = err["w"]
    np.testing.assert_allclose(np.asarray(total_sent + resid),
                               np.asarray(truth), rtol=1e-4, atol=1e-4)


def test_topk_keeps_largest():
    g = jnp.asarray([0.1, -5.0, 0.2, 3.0, -0.05, 1.0])
    kept = topk_roundtrip(g, frac=0.34)      # k = 2
    assert float(kept[1]) == -5.0 and float(kept[3]) == 3.0
    assert float(jnp.sum(kept != 0)) == 2


def test_training_converges_with_compression():
    """A linear-regression step with int8+EF reaches the same loss basin."""
    key = jax.random.PRNGKey(2)
    X = jax.random.normal(key, (256, 16))
    w_true = jax.random.normal(jax.random.fold_in(key, 1), (16,))
    y = X @ w_true

    def loss(w):
        return jnp.mean((X @ w - y) ** 2)

    transform, init_err = make_int8_transform()
    w_plain = jnp.zeros((16,))
    w_comp = jnp.zeros((16,))
    err = init_err({"w": w_comp})
    for _ in range(200):
        g = jax.grad(loss)(w_plain)
        w_plain = w_plain - 0.05 * g
        g2 = jax.grad(loss)(w_comp)
        sent, err = transform({"w": g2}, err)
        w_comp = w_comp - 0.05 * sent["w"]
    assert float(loss(w_comp)) < 1e-2
    assert abs(float(loss(w_comp)) - float(loss(w_plain))) < 1e-2
