"""Data pipeline: determinism, sharding coverage, resumability,
straggler-aware rebalancing (property-based)."""
import numpy as np
import pytest
pytest.importorskip("hypothesis", reason="property-based tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.data.pipeline import (
    DataPipeline, PipelineState, SyntheticLMSource, shard_plan,
)


def test_deterministic_batches():
    src = SyntheticLMSource(1000)
    a = src.sequence_batch(seed=7, start_seq=10, n_seqs=4, seq_len=16)
    b = src.sequence_batch(seed=7, start_seq=10, n_seqs=4, seq_len=16)
    assert np.array_equal(a, b)
    c = src.sequence_batch(seed=8, start_seq=10, n_seqs=4, seq_len=16)
    assert not np.array_equal(a, c)


def test_shards_cover_global_batch():
    """Concatenated rank shards == the global batch (no loss, no overlap)."""
    pipe = DataPipeline(SyntheticLMSource(500), global_batch=16, seq_len=8)
    global_block = pipe.global_batch_at(3)
    shards = [pipe.shard_at(3, r, 4) for r in range(4)]
    assert np.array_equal(np.concatenate(shards, 0), global_block)


def test_any_rank_can_recompute_any_shard():
    """Backup-shard property: rank identity does not matter."""
    pipe = DataPipeline(SyntheticLMSource(500), global_batch=12, seq_len=8)
    s2 = pipe.shard_at(5, 2, 3)
    pipe2 = DataPipeline(SyntheticLMSource(500), global_batch=12, seq_len=8)
    assert np.array_equal(s2, pipe2.shard_at(5, 2, 3))


def test_resume_mid_epoch():
    p1 = DataPipeline(SyntheticLMSource(100), 4, 8)
    seen = [p1.next_global()["tokens"] for _ in range(5)]
    # resume from the saved state after step 2
    p2 = DataPipeline(SyntheticLMSource(100), 4, 8,
                      state=PipelineState(seed=0, step=2))
    resumed = [p2.next_global()["tokens"] for _ in range(3)]
    for a, b in zip(seen[2:], resumed):
        assert np.array_equal(a, b)


@settings(max_examples=50, deadline=None)
@given(st.integers(1, 512), st.integers(1, 32))
def test_shard_plan_partitions(global_batch, n_ranks):
    plan = shard_plan(global_batch, n_ranks)
    assert len(plan) == n_ranks
    assert sum(c for _, c in plan) == global_batch
    # contiguous, ordered, non-overlapping
    pos = 0
    for start, count in plan:
        assert start == pos and count >= 0
        pos += count


def test_straggler_rebalancing():
    """A slow rank (weight 0.5) gets a smaller shard."""
    plan = shard_plan(100, 4, weights=[1, 1, 1, 0.5])
    counts = [c for _, c in plan]
    assert counts[3] < counts[0]
    assert sum(counts) == 100


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 10_000), st.integers(1, 64))
def test_tokens_in_vocab(seed, vocab):
    src = SyntheticLMSource(vocab)
    batch = src.sequence_batch(seed, 0, 3, 10)
    assert batch.min() >= 0 and batch.max() < vocab
