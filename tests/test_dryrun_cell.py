"""Dry-run machinery sanity (full 80-cell sweep runs via
`python -m repro.launch.dryrun --all --mesh both`; this test keeps one
fast cell under pytest in a subprocess with the 512-device env)."""
import json
import os
import subprocess
import sys
import textwrap

SCRIPT = textwrap.dedent("""
    import json
    from repro.launch.dryrun import run_cell
    r = run_cell("olmo-1b", "decode_32k", "single", with_probes=True)
    print(json.dumps({
        "ok": r.ok, "err": (r.error or "")[-400:],
        "mem": r.bytes_per_device, "p1": r.probe1, "p2": r.probe2,
        "n_periods": r.n_periods, "kinds": r.collective_kinds,
        "unresolved": r.unresolved_trip}))
""")


def test_one_dryrun_cell():
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    env.pop("XLA_FLAGS", None)      # dryrun sets its own 512-device flag
    proc = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-3000:]
    out = json.loads(proc.stdout.strip().splitlines()[-1])
    assert out["ok"], out["err"]
    assert out["n_periods"] == 16
    # decode fits comfortably in HBM
    assert out["mem"] < 16e9
    # probes carry the three roofline ingredients
    for p in (out["p1"], out["p2"]):
        assert p["flops"] > 0 and p["bytes"] > 0
    # per-period deltas are positive (deeper probe costs more)
    assert out["p2"]["flops"] > out["p1"]["flops"]


def test_mesh_shapes():
    src = open(os.path.join(os.path.dirname(__file__), "..", "src",
                            "repro", "launch", "mesh.py")).read()
    assert "(2, 16, 16)" in src and "(16, 16)" in src
    assert '("pod", "data", "model")' in src


def test_dryrun_sets_device_flag_first():
    """The XLA flag must be set before any jax import (assignment §0)."""
    src = open(os.path.join(os.path.dirname(__file__), "..", "src",
                            "repro", "launch", "dryrun.py")).read()
    flag_pos = src.index("xla_force_host_platform_device_count")
    jax_pos = src.index("import jax")
    assert flag_pos < jax_pos
