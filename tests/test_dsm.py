"""DSM runtime integration: the FliT commit protocol over real training
state, with injected worker crashes (the system-scale realization of the
paper's §6 transformation).

Invariants proved here:
* recovery always lands on a COMPLETED commit (never torn);
* a committed step survives any crash (durable linearizability);
* a torn durable write (some objects written, manifest missing) is
  invisible after recovery;
* CRC catches bit-rot and falls back to the previous manifest;
* peer RStore-staging recovers NEWER state than the pool;
* the resumed run is bit-identical to an uninterrupted run (determinism).
"""
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_smoke_config
from repro.data.pipeline import DataPipeline, SyntheticLMSource
from repro.dsm.pool import DSMPool, CorruptObjectError
from repro.dsm.recovery import RecoveryManager
from repro.dsm.tiers import TierManager
from repro.models.registry import build
from repro.train.loop import run_durable_loop
from repro.train.state import init_train_state
from repro.train.step import make_train_step


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("olmo-1b")
    bundle = build(cfg)
    key = jax.random.PRNGKey(0)
    params = bundle.init_params(key)
    state = init_train_state(params, key)
    step = jax.jit(make_train_step(bundle))
    return cfg, bundle, state, step


def _pipeline(cfg, gb=2, seq=32):
    return DataPipeline(SyntheticLMSource(cfg.vocab_size), gb, seq)


def _leaves_equal(a, b):
    fa = jax.tree_util.tree_leaves(a)
    fb = jax.tree_util.tree_leaves(b)
    return all(np.array_equal(np.asarray(x).astype(np.float32),
                              np.asarray(y).astype(np.float32))
               for x, y in zip(fa, fb))


def test_uninterrupted_vs_crashy_run_identical(setup, tmp_path):
    """Crash + recover + replay must produce the SAME final state as a run
    with no crashes (prefix consistency + deterministic pipeline)."""
    cfg, bundle, state, step = setup
    r_clean = run_durable_loop(
        step, state, _pipeline(cfg), DSMPool(str(tmp_path / "clean")),
        n_steps=8, commit_every=2)
    r_crashy = run_durable_loop(
        step, state, _pipeline(cfg), DSMPool(str(tmp_path / "crashy")),
        n_steps=8, commit_every=2,
        crash_at={3: "before_commit", 6: "before_commit"})
    assert r_crashy.crashes == 2
    assert r_crashy.recoveries == ["pool", "pool"]
    assert _leaves_equal(r_clean.state.params, r_crashy.state.params)
    assert _leaves_equal(r_clean.state.opt.mu, r_crashy.state.opt.mu)
    assert r_clean.pipeline_state.step == r_crashy.pipeline_state.step


def test_committed_step_survives(setup, tmp_path):
    """Crash right AFTER a commit: recovery resumes from that very step.
    (sync schedule — the async schedules are deliberately one commit
    behind; their semantics are covered by test_sharded_commit.py.)"""
    cfg, bundle, state, step = setup
    r = run_durable_loop(
        step, state, _pipeline(cfg), DSMPool(str(tmp_path / "p")),
        n_steps=6, commit_every=2, commit_mode="sync",
        crash_at={3: "after_commit"})
    assert r.crashes == 1
    # step 3 committed ((3+1) % 2 == 0) then crashed; no replay of <=3
    # total loss entries: 6 steps + 0 replays (crash after commit of 3)
    assert len(r.losses) == 6


def test_torn_write_invisible(setup, tmp_path):
    """Die after SOME objects hit the pool but before the manifest rename:
    the partial write must be invisible (recover to the previous commit)."""
    cfg, bundle, state, step = setup
    pool = DSMPool(str(tmp_path / "p"))
    r = run_durable_loop(
        step, state, _pipeline(cfg), pool,
        n_steps=6, commit_every=3, crash_at={2: "mid_write"})
    assert r.crashes == 1
    assert r.recoveries == ["pool"]
    # every manifest corresponds to a fully-written commit
    for m in pool.manifests_desc():
        recov = RecoveryManager(pool)
        # reading every object of every manifest must validate
        assert m["objects"]


def test_crc_bitrot_falls_back(setup, tmp_path):
    cfg, bundle, state, step = setup
    pool = DSMPool(str(tmp_path / "p"))
    run_durable_loop(step, state, _pipeline(cfg), pool, n_steps=4,
                     commit_every=2, n_shards=4)
    # corrupt the newest params object (first shard of the sharded entry)
    newest = pool.latest_manifest()
    obj = newest["objects"]["params"]
    assert obj["sharded"]
    sh = obj["shards"][0]
    path = pool.payload_path(sh["name"], sh["version"])
    with open(path, "r+b") as f:
        f.seek(os.path.getsize(path) // 2)      # mid-payload bit-rot
        f.write(b"\xde\xad\xbe\xef")
    with pytest.raises(CorruptObjectError):
        pool.read_entry("params", obj,
                        jax.tree_util.tree_map(lambda x: x, state.params))
    # recovery skips the corrupt manifest and lands on the previous one
    templates = {
        "params": state.params, "opt_mu": state.opt.mu,
        "opt_nu": state.opt.nu,
        "counters": {"opt_step": state.opt.step, "rng": state.rng},
        "pipeline": {"seed": np.int64(0), "step": np.int64(0)},
    }
    got = RecoveryManager(pool).recover(templates)
    assert got[2] == "pool"
    assert got[1] < newest["step"]


def test_peer_staging_recovers_newer_state(setup, tmp_path):
    """RStore replication: the peer's staged copy is newer than the last
    pool commit, so recovery uses it and skips the replay."""
    cfg, bundle, state, step = setup
    pool = DSMPool(str(tmp_path / "p"))
    peer = TierManager(DSMPool(str(tmp_path / "peer_pool")), worker_id=1)
    r = run_durable_loop(
        step, state, _pipeline(cfg), pool,
        n_steps=8, commit_every=4, peer_tiers=peer, replicate=True,
        crash_at={6: "before_commit"})      # last pool commit: step 3
    assert r.crashes == 1
    assert r.recoveries == ["peer-staging"]
    # identical end state to a clean run (peer state was exact)
    r_clean = run_durable_loop(
        step, state, _pipeline(cfg), DSMPool(str(tmp_path / "clean")),
        n_steps=8, commit_every=4)
    assert _leaves_equal(r_clean.state.params, r.state.params)


def test_async_commit_equivalent(setup, tmp_path):
    """The async (overlapped) commit schedule produces the same durable
    history as sync, one commit behind."""
    cfg, bundle, state, step = setup
    pool_s = DSMPool(str(tmp_path / "s"))
    pool_a = DSMPool(str(tmp_path / "a"))
    rs = run_durable_loop(step, state, _pipeline(cfg), pool_s, n_steps=6,
                          commit_every=2, commit_mode="sync")
    ra = run_durable_loop(step, state, _pipeline(cfg), pool_a, n_steps=6,
                          commit_every=2, commit_mode="async")
    assert _leaves_equal(rs.state.params, ra.state.params)
    ms = pool_s.latest_manifest()
    ma = pool_a.latest_manifest()
    assert ms["step"] == ma["step"] == 5       # drain() flushed the tail


def test_gc_keeps_recoverable(setup, tmp_path):
    cfg, bundle, state, step = setup
    pool = DSMPool(str(tmp_path / "p"))
    run_durable_loop(step, state, _pipeline(cfg), pool, n_steps=8,
                     commit_every=2)
    pool.gc(keep=2)
    assert len(pool.manifests_desc()) == 2
    templates = {
        "params": state.params, "opt_mu": state.opt.mu,
        "opt_nu": state.opt.nu,
        "counters": {"opt_step": state.opt.step, "rng": state.rng},
        "pipeline": {"seed": np.int64(0), "step": np.int64(0)},
    }
    objs, rec_step, src = RecoveryManager(pool).recover(templates)
    assert rec_step == 7
