"""Unit tests for the durable-linearizability checker itself (it must
accept/reject hand-built histories correctly, or every other verdict is
meaningless)."""
from repro.core.durable import (
    collect_ops, durably_linearizable, linearizable, well_formed,
)
from repro.core.objects import CounterSpec, RegisterSpec, StackSpec, EMPTY
from repro.core.sim import Event


def H(*evs):
    return list(evs)


def inv(t, oid, op, *args):
    return Event("inv", t, oid, op, tuple(args))


def res(t, oid, r=None):
    return Event("res", t, oid, result=r)


def crash(m):
    return Event("crash", machine=m)


def test_sequential_counter_ok():
    h = H(inv(0, 0, "inc"), res(0, 0, 0), inv(0, 1, "read"), res(0, 1, 1))
    assert durably_linearizable(h, CounterSpec())


def test_lost_update_rejected():
    # inc completed (returned), then a later read misses it -> not lin.
    h = H(inv(0, 0, "inc"), res(0, 0, 0),
          crash(0),
          inv(1, 1, "read"), res(1, 1, 0))
    assert not durably_linearizable(h, CounterSpec())


def test_pending_op_may_be_dropped():
    # inc has no response (crash mid-op): read seeing 0 is fine
    h = H(inv(0, 0, "inc"), crash(0), inv(1, 1, "read"), res(1, 1, 0))
    assert durably_linearizable(h, CounterSpec())


def test_pending_op_may_take_effect():
    # ... and read seeing 1 is also fine (pending op linearized)
    h = H(inv(0, 0, "inc"), crash(0), inv(1, 1, "read"), res(1, 1, 1))
    assert durably_linearizable(h, CounterSpec())


def test_concurrent_overlap_allows_reordering():
    # two overlapping writes: either order OK for a later read
    h = H(inv(0, 0, "write", 1), inv(1, 1, "write", 2),
          res(0, 0), res(1, 1),
          inv(0, 2, "read"), res(0, 2, 1))
    assert linearizable(h, RegisterSpec())
    h2 = h[:-1] + [res(0, 2, 2)]
    assert linearizable(h2, RegisterSpec())


def test_realtime_order_enforced():
    # write(1) completes BEFORE write(2) is invoked; read=1 afterwards bad
    h = H(inv(0, 0, "write", 1), res(0, 0),
          inv(1, 1, "write", 2), res(1, 1),
          inv(0, 2, "read"), res(0, 2, 1))
    assert not linearizable(h, RegisterSpec())


def test_stack_lifo():
    h = H(inv(0, 0, "push", 5), res(0, 0),
          inv(0, 1, "push", 6), res(0, 1),
          inv(1, 2, "pop"), res(1, 2, 6),
          inv(1, 3, "pop"), res(1, 3, 5),
          inv(1, 4, "pop"), res(1, 4, EMPTY))
    assert linearizable(h, StackSpec())
    bad = h[:5] + [res(1, 2, 5)] + h[6:]
    assert not linearizable(bad, StackSpec())


def test_well_formedness():
    assert well_formed(H(inv(0, 0, "read"), res(0, 0, 0)))
    assert well_formed(H(inv(0, 0, "read"), crash(0)))        # pending OK
    assert not well_formed(H(inv(0, 0, "read"), inv(0, 1, "read")))
    assert not well_formed(H(res(0, 0, 0)))


def test_collect_ops_marks_pending():
    ops = collect_ops(H(inv(0, 0, "inc"), crash(0)))
    assert len(ops) == 1 and not ops[0].completed
