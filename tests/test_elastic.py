"""Elastic scaling: recover state from the pool and re-shard onto a
different mesh — shrink (8 -> 4 devices) AND grow (4 -> 8).  The mesh
tests run in subprocesses; the 8-device host force is inherited from
the environment (set once in conftest.py).  Plan symmetry (grow then
shrink returns the original partition) is pure and runs in-process."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

from repro.train.elastic import grow_plan, partition_plan, plan_delta

SCRIPT = textwrap.dedent("""
    import os
    import json
    import numpy as np
    import jax, jax.numpy as jnp

    from repro.configs import get_smoke_config
    from repro.data.pipeline import DataPipeline, SyntheticLMSource, shard_plan
    from repro.dsm.pool import DSMPool
    from repro.dsm.recovery import RecoveryManager
    from repro.models.registry import build
    from repro.parallel.sharding import ctx_for_mesh
    from repro.train.elastic import remesh, shardings_for, shrink_plan
    from repro.train.loop import run_durable_loop, _state_objects
    from repro.train.state import init_train_state
    from repro.train.step import make_train_step

    cfg = get_smoke_config("olmo-1b")
    bundle = build(cfg)
    key = jax.random.PRNGKey(0)

    # --- run on the 8-device mesh, committing durably -------------------
    mesh8 = jax.make_mesh((4, 2), ("data", "model"))
    ctx8 = ctx_for_mesh(mesh8)
    params = bundle.init_params(key)
    sh8 = shardings_for(ctx8, bundle.descs)
    params = jax.tree_util.tree_map(jax.device_put, params, sh8)
    state = init_train_state(params, key)
    step8 = jax.jit(make_train_step(bundle, ctx8))
    pool = DSMPool(os.environ["POOL_DIR"])
    pipe = DataPipeline(SyntheticLMSource(cfg.vocab_size), 8, 32)
    r = run_durable_loop(step8, state, pipe, pool, n_steps=4, commit_every=2)

    # --- "cluster shrinks": rebuild on a 4-device mesh ------------------
    mesh4 = jax.make_mesh((2, 2), ("data", "model"))
    templates = _state_objects(r.state, r.pipeline_state)
    objs, rec_step, src = RecoveryManager(pool).recover(templates)
    assert rec_step == 3, rec_step

    new_params, ctx4 = remesh(objs["params"], bundle.descs, mesh4)
    # every leaf is now addressable on the 4-device mesh
    for leaf in jax.tree_util.tree_leaves(new_params):
        assert len(leaf.sharding.device_set) <= 4

    # training continues on the shrunk mesh from the recovered state
    state4 = init_train_state(new_params, key)
    state4 = state4._replace(opt=state4.opt._replace(
        step=jnp.asarray(objs["counters"]["opt_step"])))
    step4 = jax.jit(make_train_step(bundle, ctx4))
    batch = {k: jnp.asarray(v) for k, v in pipe.next_global().items()}
    state4, m = step4(state4, batch)
    assert bool(jnp.isfinite(m["loss"]))

    # data shard plan reassigns the lost ranks
    plan = shrink_plan(8, 4)
    assert all(0 <= v < 4 for v in plan.values())
    print(json.dumps({"ok": True, "rec_step": rec_step,
                      "loss": float(m["loss"]), "source": src}))
""")


GROW_SCRIPT = textwrap.dedent("""
    import os
    import json
    import numpy as np
    import jax, jax.numpy as jnp

    from repro.configs import get_smoke_config
    from repro.data.pipeline import DataPipeline, SyntheticLMSource
    from repro.dsm.pool import DSMPool
    from repro.dsm.recovery import RecoveryManager
    from repro.models.registry import build
    from repro.parallel.sharding import ctx_for_mesh
    from repro.train.elastic import grow_plan, remesh, shardings_for
    from repro.train.loop import run_durable_loop, _state_objects
    from repro.train.state import init_train_state
    from repro.train.step import make_train_step

    cfg = get_smoke_config("olmo-1b")
    bundle = build(cfg)
    key = jax.random.PRNGKey(0)

    # --- run on a 4-device mesh, committing durably ---------------------
    mesh4 = jax.make_mesh((2, 2), ("data", "model"))
    ctx4 = ctx_for_mesh(mesh4)
    params = bundle.init_params(key)
    sh4 = shardings_for(ctx4, bundle.descs)
    params = jax.tree_util.tree_map(jax.device_put, params, sh4)
    state = init_train_state(params, key)
    step4 = jax.jit(make_train_step(bundle, ctx4))
    pool = DSMPool(os.environ["POOL_DIR"])
    pipe = DataPipeline(SyntheticLMSource(cfg.vocab_size), 8, 32)
    r = run_durable_loop(step4, state, pipe, pool, n_steps=4, commit_every=2)

    # --- "cluster grows": rebuild on the full 8-device mesh -------------
    mesh8 = jax.make_mesh((4, 2), ("data", "model"))
    templates = _state_objects(r.state, r.pipeline_state)
    objs, rec_step, src = RecoveryManager(pool).recover(templates)
    assert rec_step == 3, rec_step

    new_params, ctx8 = remesh(objs["params"], bundle.descs, mesh8)
    # every leaf is now spread over the grown device set
    for leaf in jax.tree_util.tree_leaves(new_params):
        assert len(leaf.sharding.device_set) <= 8

    # training continues on the grown mesh from the recovered state
    state8 = init_train_state(new_params, key)
    state8 = state8._replace(opt=state8.opt._replace(
        step=jnp.asarray(objs["counters"]["opt_step"])))
    step8 = jax.jit(make_train_step(bundle, ctx8))
    batch = {k: jnp.asarray(v) for k, v in pipe.next_global().items()}
    state8, m = step8(state8, batch)
    assert bool(jnp.isfinite(m["loss"]))

    # data shard plan: old ranks keep their identity, joiners start fresh
    plan = grow_plan(4, 8)
    assert plan == {r: r for r in range(4)}
    print(json.dumps({"ok": True, "rec_step": rec_step,
                      "loss": float(m["loss"]), "source": src}))
""")


def _run_script(script, tmp_path):
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..", "src"),
               POOL_DIR=str(tmp_path / "pool"))
    proc = subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-3000:]
    return json.loads(proc.stdout.strip().splitlines()[-1])


def test_elastic_shrink_8_to_4(tmp_path):
    out = _run_script(SCRIPT, tmp_path)
    assert out["ok"] and out["rec_step"] == 3


def test_elastic_grow_4_to_8(tmp_path):
    out = _run_script(GROW_SCRIPT, tmp_path)
    assert out["ok"] and out["rec_step"] == 3


def test_partition_plan_grow_then_shrink_is_identity():
    """Membership round-trips: growing to 4 ranks and shrinking back to
    3 derives the ORIGINAL partition — the plan is a pure function of
    the live set, so a failed grow leaves nothing to repair."""
    names = [f"t{i}" for i in range(9)]
    old = partition_plan(names, [0, 1, 2])
    grown = partition_plan(names, [0, 1, 2, 3])
    assert partition_plan(names, [0, 1, 2]) == old
    fwd = plan_delta(old, grown)
    back = plan_delta(grown, old)
    assert set(fwd) == set(back)
    assert all(back[n] == (fwd[n][1], fwd[n][0]) for n in fwd)


def test_grow_plan_keeps_old_rank_identity():
    assert grow_plan(4, 8) == {0: 0, 1: 1, 2: 2, 3: 3}
    with pytest.raises(AssertionError):
        grow_plan(8, 4)                       # that's shrink_plan's job
