"""Emulated CXL topologies (repro.dsm.emu): preset taxonomy, pricing
model shape, and — the property the CI bench gate stands on — trace
determinism: the same (topology, seed, op sequence) always produces the
identical priced trace, while instrumentation never changes TierManager
behaviour."""
import numpy as np
import pytest

from repro.core.latency import HOST, LATENCY_NS
from repro.dsm.emu import (PRESETS, TopologyEmulator, attach_emulator,
                           get_topology, lstore_ns, rflush_ns, rstore_ns,
                           rload_pool_ns, sharded_flush_ns, tree_nbytes)
from repro.dsm.pool import DSMPool
from repro.dsm.tiers import TierManager


# ---------------------------------------------------------------------------
# presets
# ---------------------------------------------------------------------------

def test_three_presets_span_the_taxonomy():
    assert set(PRESETS) == {"cxl11-direct", "cxl20-switched-pool",
                            "cxl30-fabric"}
    gens = {t.generation for t in PRESETS.values()}
    assert gens == {"1.1", "2.0", "3.0"}
    # the 1.1 preset IS the paper's calibrated pair: no scaling, no hop
    direct = PRESETS["cxl11-direct"]
    assert direct.remote_multiplier == 1.0
    assert direct.switch_hop_ns == 0.0
    assert direct.n_links == 1


def test_presets_differ_in_remote_cost_and_fanout():
    d, s, f = (PRESETS["cxl11-direct"], PRESETS["cxl20-switched-pool"],
               PRESETS["cxl30-fabric"])
    # deeper topologies pay more per remote access...
    lat = [rflush_ns(t, 0) for t in (d, s, f)]
    assert lat[0] < lat[1] < lat[2]
    # ...but fan out wider
    assert d.n_links < s.n_links < f.n_links
    assert (d.aggregate_bw_gbps(8) < s.aggregate_bw_gbps(8)
            < f.aggregate_bw_gbps(8))


def test_direct_preset_matches_calibrated_table_at_zero_bytes():
    t = get_topology("cxl11-direct")
    assert rflush_ns(t, 0) == LATENCY_NS[(HOST, "rflush", "remote")]
    assert lstore_ns(t, 0) == LATENCY_NS[(HOST, "lstore", "local")]


def test_get_topology_rejects_unknown():
    with pytest.raises(KeyError):
        get_topology("cxl99-imaginary")


# ---------------------------------------------------------------------------
# pricing model shape
# ---------------------------------------------------------------------------

def test_costs_monotone_in_bytes():
    for t in PRESETS.values():
        for fn in (lstore_ns, rstore_ns, rflush_ns, rload_pool_ns):
            assert fn(t, 1 << 20) < fn(t, 8 << 20)


def test_sharding_beyond_links_never_helps():
    for t in PRESETS.values():
        nb = 64 << 20
        at_links = sharded_flush_ns(t, nb, t.n_links)
        assert sharded_flush_ns(t, nb, t.n_links + 4) >= at_links
    # and on the single-link direct preset, any sharding is pure overhead
    d = PRESETS["cxl11-direct"]
    assert sharded_flush_ns(d, 64 << 20, 4) > sharded_flush_ns(d, 64 << 20, 1)


def test_tree_nbytes():
    tree = {"a": np.zeros(8, np.float32), "b": np.zeros((2, 4), np.int64)}
    assert tree_nbytes(tree) == 8 * 4 + 8 * 8


# ---------------------------------------------------------------------------
# determinism + instrumentation
# ---------------------------------------------------------------------------

def _drive(tiers, peer):
    """A fixed op sequence exercising every priced primitive."""
    a = {"x": np.arange(64, dtype=np.float32),
         "y": np.ones((8, 8), np.float32)}
    tiers.lstore("obj", a)
    tiers.rstore("obj", peer)
    tiers.rflush("obj")
    tiers.mstore("obj", a)
    tiers.rflush_sharded("obj", 2)
    tiers.flush_async("obj")
    tiers.flush_wait("obj")
    peer.rload("obj")           # peer-side read of the staged copy


def _traced_run(tmp, seed):
    emu = TopologyEmulator("cxl20-switched-pool", seed=seed)
    tiers = attach_emulator(TierManager(DSMPool(f"{tmp}/pool"), 0), emu)
    peer = attach_emulator(TierManager(DSMPool(f"{tmp}/peer"), 1),
                           emu)
    _drive(tiers, peer)
    tiers.close()
    return emu.trace


def test_same_topology_and_seed_identical_priced_trace(tmp_path):
    t1 = _traced_run(tmp_path / "a", seed=7)
    t2 = _traced_run(tmp_path / "b", seed=7)
    assert t1 == t2                      # dataclass equality: ops AND costs
    assert len(t1) > 0
    ops = [p.op for p in t1]
    for expected in ("lstore", "rstore", "rflush", "mstore",
                     "rflush_shard", "rload"):
        assert expected in ops


def test_different_seed_same_ops_different_costs(tmp_path):
    t1 = _traced_run(tmp_path / "a", seed=0)
    t2 = _traced_run(tmp_path / "b", seed=1)
    assert [p.op for p in t1] == [p.op for p in t2]
    assert [p.nbytes for p in t1] == [p.nbytes for p in t2]
    assert any(x.cost_ns != y.cost_ns for x, y in zip(t1, t2))


def test_reset_reprices_identically(tmp_path):
    emu = TopologyEmulator("cxl30-fabric", seed=3)
    tiers = attach_emulator(TierManager(DSMPool(str(tmp_path / "p")), 0),
                            emu)
    tiers.lstore("o", {"x": np.zeros(32, np.float32)})
    tiers.rflush("o")
    first = list(emu.trace)
    emu.reset()
    tiers.lstore("o", {"x": np.zeros(32, np.float32)})
    tiers.rflush("o")
    assert [p.cost_ns for p in emu.trace] == [p.cost_ns for p in first]


def test_instrumentation_preserves_behaviour(tmp_path):
    """Attaching the emulator must not change WHAT the tiers do — only
    record what it would have cost."""
    emu = TopologyEmulator("cxl11-direct")
    tiers = attach_emulator(
        TierManager(DSMPool(str(tmp_path / "pool")), 0), emu)
    tree = {"w": np.arange(16, dtype=np.float32)}
    tiers.lstore("params", tree)
    obj = tiers.rflush("params")
    assert obj.version == tiers.versions["params"]
    back = tiers.pool.read_object("params", obj.version, tree,
                                  expected_crc=obj.crc)
    np.testing.assert_array_equal(back["w"], tree["w"])
    sharded = tiers.rflush_sharded("params", 2)
    assert len(sharded.shards) >= 1
    assert tiers.emulator is emu
    assert emu.total_ns() > 0
    per_op = emu.per_op_ns()
    assert per_op["lstore"] > 0 and per_op["rflush"] > 0
