"""Examples-smoke: every example executes headless end to end, so API
drift in the examples can never recur (they are real programs against the
public surface, not snippets).  Budgets are kept small via CLI flags; the
CI `examples-smoke` job runs exactly this module."""
import os
import subprocess
import sys

REPO = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
EXAMPLES = os.path.join(REPO, "examples")


def run_example(name, *args, timeout=900):
    env = dict(os.environ)
    env["PYTHONPATH"] = (os.path.join(REPO, "src") + os.pathsep
                         + env.get("PYTHONPATH", ""))
    env.setdefault("JAX_PLATFORMS", "cpu")
    proc = subprocess.run(
        [sys.executable, os.path.join(EXAMPLES, name), *args],
        env=env, capture_output=True, text=True, timeout=timeout)
    assert proc.returncode == 0, (
        f"{name} failed (rc={proc.returncode})\n"
        f"stdout: {proc.stdout[-2000:]}\nstderr: {proc.stderr[-2000:]}")
    return proc.stdout


def test_quickstart_runs_all_four_acts():
    out = run_example("quickstart.py")
    assert "Act 1" in out and "Act 4" in out
    assert "durably linearizable" in out


def test_durable_kv_example():
    out = run_example("durable_kv.py")
    assert "recovered state == acknowledged" in out


def test_train_durable_example():
    out = run_example("train_durable.py", "--steps", "8")
    assert "identical to clean run: True" in out


def test_serve_example():
    out = run_example("serve.py", "--requests", "4", "--slots", "2")
    assert "requests" in out and "tokens" in out
