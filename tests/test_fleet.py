"""Fleet serving: N engines over one pool (repro.serve.fleet).

* routing     — every admission is a logged cost decision; the fleet's
  outputs equal a single engine serving the same trace (bit-identity is
  batching- and placement-independent);
* migration   — a live four-phase handoff loses no tokens, and a kill at
  any phase followed by a fleet restart still finishes the identical
  token streams, whichever arm (staging or pool) the adoption reads.
  The full 4-point x {kept, wiped} matrix runs in the scenario runner
  (``--suite serve --engines 2``); here a reduced in-process matrix
  keeps tier-1 runtime bounded while covering both staging outcomes on
  both sides of the ownership transfer;
* admission   — cost-routed placement balances a backlog across engines.
"""
import jax
import pytest

from repro.serve.fleet import (FleetController, MIGRATION_POINTS)
from repro.serve.trace import synthetic_trace, trace_t_max

ARCH = "olmo-1b"
T_KW = dict(prompt_lens=(8,), new_tokens=(4, 8, 12), seed=5)
N_REQS = 6


@pytest.fixture(scope="module")
def smoke():
    from repro.configs import get_smoke_config
    from repro.models.registry import build
    cfg = get_smoke_config(ARCH)
    trace = synthetic_trace(N_REQS, vocab_size=cfg.vocab_size, **T_KW)
    t_max = trace_t_max(trace)
    bundle = build(cfg, dec_pos_len=t_max)
    params = bundle.init_params(jax.random.PRNGKey(0))
    return cfg, bundle, params, trace, t_max


@pytest.fixture(scope="module")
def reference_outputs(smoke):
    """One engine, no store — the fleet's bit-identity oracle."""
    from repro.serve.engine import ServeEngine
    _, bundle, params, trace, t_max = smoke
    return ServeEngine(bundle, params, n_slots=2,
                       t_max=t_max).run(trace).outputs


def _fleet(smoke, pool, **kw):
    _, bundle, params, _, t_max = smoke
    return FleetController(ARCH, pool_path=str(pool), n_engines=2,
                           n_slots=2, t_max=t_max, commit_every=2,
                           bundle=bundle, params=params, **kw)


def test_fleet_matches_single_engine_and_logs_admissions(
        smoke, reference_outputs, tmp_path):
    _, _, _, trace, _ = smoke
    fl = _fleet(smoke, tmp_path / "pool")
    res = fl.run(trace, rebalance=False)
    fl.close()
    assert res.outputs == reference_outputs
    admits = fl.policy.decisions_for("admit")
    assert [d.name for d in admits] == [r.rid for r in trace]
    # every decision carries both engines' modelled costs and picked the
    # cheapest (ties to the lowest engine id)
    for d in admits:
        assert set(d.costs) == {"e1", "e2"}
        assert d.costs[d.choice] == min(d.costs.values())
    # the cost routing actually spread the backlog: both engines served
    assert all(len(r.outputs) > 0 for r in res.per_engine.values())


def test_fleet_live_migration_loses_no_tokens(smoke, reference_outputs,
                                              tmp_path):
    """Force one handoff mid-decode: the moved session finishes on the
    TARGET engine with exactly the tokens the uninterrupted single-engine
    run emits."""
    _, _, _, trace, _ = smoke
    fl = _fleet(smoke, tmp_path / "pool")
    fl.submit(trace)
    moved = None
    while not fl.done:
        fl.tick(rebalance=False)
        if moved is None and fl.engines[1]._tick >= 3:
            src = fl.engines[1]
            moved = next((r for r in src.sched.admission_order
                          if r in src.sched.running), None)
            if moved is not None:
                fl.migrate(moved, 1, 2)
    res = fl.finish()
    fl.close()
    assert moved is not None
    assert res.outputs == reference_outputs
    assert res.migrations == 1
    assert [p for p, r, *_ in fl.migration_log if r == moved] \
        == list(MIGRATION_POINTS)
    # ownership moved: the target delivered the session's tokens
    assert moved in res.per_engine[2].outputs
    assert moved not in res.per_engine[1].outputs
    assert res.per_engine[2].migrated_in == 1
    assert res.per_engine[1].migrated_out == 1


class _Kill(Exception):
    pass


@pytest.mark.parametrize("point,wipe", [
    ("mig_stage", False),        # pre-handoff: source still owns
    ("mig_commit", True),        # ownership just moved; staging lost ->
    #                              the restart adopts from the POOL arm
    ("mig_adopt", True),         # adoption committed; wipe is a no-op
    ("mig_release", False),      # source copy still present: tombstone
])
def test_fleet_kill_during_migration_bit_identical(
        smoke, reference_outputs, tmp_path, point, wipe):
    """Kill the whole fleet right after ``point`` of a live handoff,
    optionally losing the target's staging buffer, then restart a fresh
    fleet over the pool: resume() re-establishes exactly-one-owner and
    the finished token streams equal the uninterrupted run."""
    _, _, _, trace, _ = smoke
    pool = tmp_path / "pool"

    def mig_hook(p, rid=None, src=None, dst=None):
        if p == point:
            raise _Kill()

    fl = _fleet(smoke, pool, mig_hook=mig_hook)
    fl.submit(trace)
    with pytest.raises(_Kill):
        while not fl.done:
            fl.tick(rebalance=False)
            if fl.engines[1]._tick >= 3:
                rid = next(r for r in fl.engines[1].sched.admission_order
                           if r in fl.engines[1].sched.running)
                fl.migrate(rid, 1, 2)
    # the fleet process is dead: in-memory engines are abandoned, only
    # the pool directory (manifests + objects + staging) survives
    fl2 = _fleet(smoke, pool)
    if wipe:
        fl2.staging.wipe(2)
    steps = fl2.resume()
    assert any(s is not None for s in steps.values())
    res = fl2.run(trace)
    fl2.close()
    assert res.outputs == reference_outputs
    # exactly-one-owner after recovery: no session is double-served
    served = [rid for r in res.per_engine.values() for rid in r.outputs]
    assert len(served) == len(set(served)) == len(trace)


def test_fleet_restart_is_idempotent_after_clean_run(smoke, tmp_path,
                                                     reference_outputs):
    """Resuming over a COMPLETED fleet pool returns every output from
    the committed tables without recomputation — and without tripping
    the handoff completion."""
    _, _, _, trace, _ = smoke
    fl = _fleet(smoke, tmp_path / "pool")
    fl.run(trace, rebalance=False)
    fl.close()
    fl2 = _fleet(smoke, tmp_path / "pool")
    fl2.resume()
    res = fl2.run(trace)
    fl2.close()
    assert res.outputs == reference_outputs
    assert sum(r.prefills for r in res.per_engine.values()) == 0
    assert sum(r.decode_ticks for r in res.per_engine.values()) == 0
