"""The paper's §6 transformation claim, end to end:

* FliT-for-CXL0 (Alg. 2) and MStore-everything yield durably linearizable
  histories on EVERY random schedule with partial crashes;
* the untransformed object and naively-ported original FliT (LFlush-based)
  exhibit durability violations — the §6 motivating example.
"""
import pytest

from repro.core.flit import DURABLE_POLICIES, NON_DURABLE_POLICIES
from repro.core.harness import WORKLOADS, run_once
from repro.core.semantics import Variant

SEEDS = range(120)


@pytest.mark.parametrize("workload", list(WORKLOADS), ids=str)
@pytest.mark.parametrize("policy", DURABLE_POLICIES)
def test_durable_policies_never_violate(workload, policy):
    mk = WORKLOADS[workload]
    crashed_runs = 0
    for seed in SEEDS:
        r = run_once(mk, policy, seed, p_crash=0.06, max_crashes=1)
        crashed_runs += r.crashed
        assert r.durable, (
            f"{policy} produced a non-durably-linearizable history on "
            f"{workload} (seed {seed}):\n"
            + "\n".join(repr(e) for e in r.history))
    assert crashed_runs > 10, "crash injection did not exercise crashes"


@pytest.mark.parametrize("workload", ["counter", "stack"])
@pytest.mark.parametrize("policy", NON_DURABLE_POLICIES)
def test_negative_controls_violate(workload, policy):
    """raw / original-FliT MUST lose completed operations on some schedule
    — otherwise the checker is vacuous."""
    mk = WORKLOADS[workload]
    violations = sum(
        not run_once(mk, policy, seed, p_crash=0.10, max_crashes=2).durable
        for seed in range(250))
    assert violations > 0, (
        f"{policy} on {workload}: expected at least one durability "
        f"violation across 250 seeds")


@pytest.mark.parametrize("policy", DURABLE_POLICIES)
def test_durable_under_lwb(policy):
    """Alg. 2 stays correct under the LWB hardware variant (a *stronger*
    model: remote loads imply write-back)."""
    mk = WORKLOADS["counter"]
    for seed in range(60):
        r = run_once(mk, policy, seed, variant=Variant.LWB, p_crash=0.06,
                     max_crashes=1)
        assert r.durable, (policy, seed)


def test_finding_flit_window_race_base():
    """FINDING 1 (beyond the paper, surfaced by our checker): under the
    UNRESTRICTED partial-crash model — no failure-atomic store→flush window
    — Alg. 2 is not durably linearizable even in CXL0-BASE.  Sequence: the
    LStore'd value is nondeterministically evicted into the owner's cache;
    the owner crashes; the issuer's RFlush precondition (no cache holds x)
    is then vacuously true, the op completes, and its effect is gone.  The
    paper's Condition-2 proof step ("after [the synchronous flush] it is
    guaranteed to reside in persistent memory") implicitly assumes this
    window is crash-free; Simulator(respect_atomic=True) models exactly
    that assumption, and under it the violation disappears
    (test_durable_policies_never_violate)."""
    mk = WORKLOADS["counter"]
    violations = sum(
        not run_once(mk, "flit_cxl0", seed, p_crash=0.15, max_crashes=3,
                     p_tau=0.5, respect_atomic=False).durable
        for seed in range(400))
    assert violations > 0, "expected the store→flush window race"


def test_finding_flit_not_durable_under_psn():
    """FINDING 2: under CXL0^PSN the same window race is easier to hit —
    the owner's crash POISONS the in-flight update held in a *surviving*
    machine's cache directly (no eviction needed); the survivor's RFlush
    passes vacuously and the completed operation's effect is destroyed.

    The PSN-safe discipline is MStore-class operations (below) — consistent
    with the paper's §4 guidance for pools without reliable coherence."""
    mk = WORKLOADS["counter"]
    violations = sum(
        not run_once(mk, "flit_cxl0", seed, variant=Variant.PSN,
                     p_crash=0.06, max_crashes=1,
                     respect_atomic=False).durable
        for seed in range(60))
    assert violations > 0, "expected the PSN poison-loss violation"


def test_mstore_all_durable_under_psn_unrestricted():
    """MStore bypasses caches entirely, so poison-on-crash cannot destroy a
    completed operation's effect — sound WITHOUT the atomic-window
    assumption (respect_atomic=False)."""
    mk = WORKLOADS["counter"]
    for seed in range(60):
        r = run_once(mk, "mstore_all", seed, variant=Variant.PSN,
                     p_crash=0.10, max_crashes=2, respect_atomic=False)
        assert r.durable, seed


def test_mstore_all_durable_unrestricted_base():
    for wl in ("counter", "stack"):
        for seed in range(60):
            r = run_once(WORKLOADS[wl], "mstore_all", seed, p_crash=0.12,
                         max_crashes=3, p_tau=0.5, respect_atomic=False)
            assert r.durable, (wl, seed)


def test_no_crash_all_policies_linearizable():
    """Without crashes CXL0 is sequentially consistent (paper §3.3), so even
    the raw object is (durably) linearizable."""
    for workload, mk in WORKLOADS.items():
        for policy in (*DURABLE_POLICIES, *NON_DURABLE_POLICIES):
            for seed in range(30):
                r = run_once(mk, policy, seed, p_crash=0.0, max_crashes=0)
                assert r.crashed == 0
                assert r.durable, (workload, policy, seed)


def test_multi_crash_durable():
    """Simultaneous/multiple failures = consecutive local crashes (§6)."""
    mk = WORKLOADS["counter"]
    crashed = 0
    for seed in range(80):
        r = run_once(mk, "flit_cxl0", seed, p_crash=0.12, max_crashes=3)
        crashed += r.crashed
        assert r.durable, seed
    assert crashed > 40
