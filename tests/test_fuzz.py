"""The adversarial crash fuzzer's own properties: episodes are a pure
function of (seed, config, schedule); the invariant holds on the real
stack under kills + torn writes; a deliberately broken recovery seam
(REPRO_FUZZ_BREAK_RECOVERY) is CAUGHT, shrunk, and its minimal
reproducer replays to the same violation; the runner propagates fuzz
violations as a nonzero exit."""
import json
import os
import subprocess
import sys

import pytest

from repro.dsm.faults import (FaultInjector, FaultSchedule, FaultyPool,
                              InjectedCrash, KillSpec, StragglerSpec,
                              TornSpec)
from repro.scenarios.fuzz import (BREAK_ENV, EpisodeConfig, dump_reproducer,
                                  make_episode, replay_reproducer,
                                  run_episode, run_fuzz_suite)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ---------------------------------------------------------------------------
# determinism
# ---------------------------------------------------------------------------

def test_make_episode_is_pure_in_the_seed_path():
    a = make_episode([7, 3, 0, 1], "train", "cxl20-switch")
    b = make_episode([7, 3, 0, 1], "train", "cxl20-switch")
    assert a == b
    drawn = {make_episode([7, ep, 0, 1], "train", "cxl20-switch")[1]
             for ep in range(8)}
    assert len(drawn) > 1, "8 episode draws produced one schedule"


@pytest.mark.parametrize("workload", ["train", "serve", "cluster"])
def test_episode_replay_is_bit_deterministic(workload, tmp_path):
    cfg, sched = make_episode([0, 1, 0, 0], workload, "cxl11-direct")
    r1 = run_episode(cfg, sched, str(tmp_path / "a"))
    r2 = run_episode(cfg, sched, str(tmp_path / "b"))
    assert r1.to_json() == r2.to_json()


def test_torn_decisions_hash_identity_not_call_order():
    spec = TornSpec(rate=0.2, salt=123)
    first = [spec.decide(f"t{i}", v) for i in range(6) for v in range(6)]
    second = [spec.decide(f"t{i}", v) for i in range(6) for v in range(6)]
    assert first == second
    assert any(m is not None for m in first), "rate=0.2 over 36 draws"
    assert StragglerSpec(rate=0.5, salt=9).perturb(3, "rflush", "x") == \
        StragglerSpec(rate=0.5, salt=9).perturb(3, "rflush", "x")


def test_schedule_json_round_trip():
    sched = FaultSchedule(
        kills=(KillSpec(worker=1, op="rflush", index=4, phase="after"),
               KillSpec(worker=0, point="mid_flush", at_step=3)),
        torn=TornSpec(rate=0.1, salt=5, modes=("bitflip",)),
        straggler=StragglerSpec(rate=0.2, salt=6))
    assert FaultSchedule.from_dict(
        json.loads(json.dumps(sched.to_dict()))) == sched


# ---------------------------------------------------------------------------
# the invariant holds on the real stack
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("workload", ["train", "serve", "cluster"])
def test_clean_episode_has_no_violations(workload, tmp_path):
    cfg = EpisodeConfig(workload=workload)
    res = run_episode(cfg, FaultSchedule(), str(tmp_path))
    assert res.ok, res.violations
    assert res.kills_fired == [] and res.torn_writes == 0
    # the forced final crash still exercises one recovery per episode
    assert res.recoveries


def test_kill_mid_commit_recovers_to_completed_commit(tmp_path):
    cfg = EpisodeConfig(workload="train", mode="sharded-async")
    sched = FaultSchedule(kills=(
        KillSpec(worker=0, op="rflush", index=5, phase="before"),))
    res = run_episode(cfg, sched, str(tmp_path))
    assert res.ok, res.violations
    assert len(res.kills_fired) == 1
    assert res.kills_fired[0]["op"] == "rflush"


def test_torn_writes_never_recovered_from(tmp_path):
    cfg = EpisodeConfig(workload="train")
    sched = FaultSchedule(
        kills=(KillSpec(worker=0, op="completeOp", index=2, phase="after"),),
        torn=TornSpec(rate=0.4, salt=11))
    res = run_episode(cfg, sched, str(tmp_path))
    assert res.ok, res.violations
    assert res.torn_writes > 0


# ---------------------------------------------------------------------------
# the invariant has teeth: a broken seam is caught + reproducible
# ---------------------------------------------------------------------------

def test_broken_recovery_is_caught_and_reproducer_replays(tmp_path,
                                                          monkeypatch):
    monkeypatch.setenv(BREAK_ENV, "1")
    cfg, sched = make_episode([0, 0, 0, 0], "train", "cxl11-direct")
    res = run_episode(cfg, sched, str(tmp_path / "run"))
    assert not res.ok, "stale-state swap at the seam went unnoticed"
    path = dump_reproducer(str(tmp_path), [0, 0, 0, 0], cfg, sched, res,
                           shrink=True)
    doc = json.load(open(path))
    assert doc["kind"] == "cxl0-fuzz-reproducer" and doc["violations"]
    replay = replay_reproducer(path)
    assert replay.violations == res.violations


def test_suite_counts_violations_and_dumps_reproducers(tmp_path,
                                                       monkeypatch):
    monkeypatch.setenv(BREAK_ENV, "1")
    s = run_fuzz_suite(str(tmp_path), episodes=1, seed=0,
                       topologies=["cxl11-direct"], workloads=["train"],
                       shrink=False)
    assert s.episodes == 1 and s.violations >= 1
    assert len(s.reproducers) == 1 and os.path.exists(s.reproducers[0])
    assert os.path.exists(s.log_path)
    logged = [json.loads(l) for l in open(s.log_path)]
    assert len(logged) == 1 and logged[0]["violations"]


# ---------------------------------------------------------------------------
# fault primitives in isolation
# ---------------------------------------------------------------------------

def test_injector_fires_at_exact_index_once():
    sched = FaultSchedule(kills=(
        KillSpec(worker=0, op="lstore", index=2, phase="before"),))
    inj = FaultInjector(sched, worker=0)
    inj.begin("lstore", "a")
    inj.begin("lstore", "b")
    with pytest.raises(InjectedCrash) as ei:
        inj.begin("lstore", "c")
    assert (ei.value.op, ei.value.index) == ("lstore", 2)
    # the spec is spent: the next incarnation's calls pass through
    for _ in range(5):
        inj.begin("lstore", "d")
    assert inj.counts["lstore"] == 8


def test_injector_ignores_other_workers():
    sched = FaultSchedule(kills=(
        KillSpec(worker=1, op="rflush", index=0, phase="before"),))
    inj0 = FaultInjector(sched, worker=0)
    inj0.begin("rflush", "x")           # not our kill
    inj1 = FaultInjector(sched, worker=1)
    with pytest.raises(InjectedCrash):
        inj1.begin("rflush", "x")


def test_killspec_validates_addressing_mode():
    with pytest.raises(ValueError):
        KillSpec(worker=0)                          # neither op nor point
    with pytest.raises(ValueError):
        KillSpec(worker=0, op="rflush", point="pre_flush")   # both
    with pytest.raises(ValueError):
        KillSpec(worker=0, op="warp")


def test_faulty_pool_ledger_matches_spec(tmp_path):
    import numpy as np
    spec = TornSpec(rate=0.5, salt=3)
    pool = FaultyPool(str(tmp_path), torn=spec)
    for v in range(1, 9):
        pool.write_object("t", v, {"a": np.arange(4.0) * v})
    expected = [("t", v, spec.decide("t", v)) for v in range(1, 9)
                if spec.decide("t", v) is not None]
    assert pool.injected == expected


# ---------------------------------------------------------------------------
# runner integration (subprocess: the real exit-code contract)
# ---------------------------------------------------------------------------

def _run_runner(workdir, extra_env=None):
    env = {**os.environ, "PYTHONPATH": os.path.join(REPO, "src"),
           "JAX_PLATFORMS": "cpu", **(extra_env or {})}
    return subprocess.run(
        [sys.executable, "-m", "repro.scenarios.runner", "--suite", "fuzz",
         "--episodes", "1", "--seed", "0", "--topology", "cxl11-direct",
         "--fuzz-workloads", "train", "--workdir", str(workdir)],
        capture_output=True, text=True, env=env, timeout=300)


def test_runner_fuzz_suite_green_exits_zero(tmp_path):
    p = _run_runner(tmp_path)
    assert p.returncode == 0, p.stdout + p.stderr
    assert "runner,OK,failed=0" in p.stdout


def test_runner_propagates_fuzz_violation_as_nonzero_exit(tmp_path):
    p = _run_runner(tmp_path, {BREAK_ENV: "1"})
    assert p.returncode != 0, p.stdout + p.stderr
    assert "runner,FAIL" in p.stdout and "fuzz_reproducer," in p.stdout
    repros = [f for f in os.listdir(tmp_path / "fuzz")
              if f.startswith("repro_") and f.endswith(".json")]
    assert repros, "violated run left no reproducer JSON"
