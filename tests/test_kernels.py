"""Per-kernel allclose sweeps against the pure-jnp oracles, plus
cross-checks of the model implementations against the same oracles.

The ``pallas_interpret`` fixture (tests/conftest.py) detects the platform:
on a real accelerator the kernels run compiled; on CPU hosts they run with
``interpret=True`` (the Pallas interpreter executes the same kernel body),
so the sweep is green everywhere instead of failing off-TPU."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.attention.kernel import flash_attention_kernel
from repro.kernels.attention.ref import attention_ref
from repro.kernels.attention.ops import flash_attention
from repro.kernels.rwkv6.kernel import wkv6_kernel
from repro.kernels.rwkv6.ref import wkv6_ref
from repro.kernels.mamba.kernel import selective_scan_kernel
from repro.kernels.mamba.ref import selective_scan_ref
from repro.kernels.moe_gmm.kernel import grouped_matmul_kernel
from repro.kernels.moe_gmm.ref import grouped_matmul_ref

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

FA_CASES = [
    # B, H, K, Sq, Sk, hd, causal, blocks
    (2, 4, 2, 128, 128, 64, True, (32, 32)),
    (1, 4, 4, 96, 96, 64, True, (32, 32)),       # MHA (K == H)
    (2, 8, 2, 64, 256, 128, False, (32, 64)),    # cross attention shape
    (1, 2, 1, 37, 53, 32, True, (16, 16)),       # ragged, needs padding
    (1, 16, 4, 64, 64, 64, True, (64, 16)),      # tall blocks
]


@pytest.mark.parametrize("case", FA_CASES, ids=lambda c: f"B{c[0]}H{c[1]}K{c[2]}S{c[3]}x{c[4]}hd{c[5]}{'c' if c[6] else 'f'}")
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16],
                         ids=["f32", "bf16"])
def test_flash_attention_sweep(case, dtype, pallas_interpret):
    B, H, K, Sq, Sk, hd, causal, (bq, bk) = case
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, H, Sq, hd), dtype)
    k = jax.random.normal(ks[1], (B, K, Sk, hd), dtype)
    v = jax.random.normal(ks[2], (B, K, Sk, hd), dtype)
    out = flash_attention_kernel(q, k, v, causal=causal, block_q=bq,
                                 block_k=bk, interpret=pallas_interpret)
    ref = attention_ref(q, k, v, causal=causal)
    tol = 0.06 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol)


def test_flash_attention_ops_layout():
    """The (B,S,K,G,hd) model-layout wrapper agrees with chunked_attention
    (the in-model streaming path)."""
    from repro.models.attention import chunked_attention
    B, S, K, G, hd = 2, 64, 2, 3, 32
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, K, G, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, K, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, K, hd), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    out_kernel = flash_attention(q, k, v, causal=True, block_q=32,
                                 block_k=32, backend="interpret")
    out_model = chunked_attention(q, (k, v), lambda kv: kv, pos, 0,
                                  causal=True, chunk=16)
    np.testing.assert_allclose(np.asarray(out_kernel, np.float32),
                               np.asarray(out_model, np.float32), atol=2e-5)


# ---------------------------------------------------------------------------
# rwkv6 wkv
# ---------------------------------------------------------------------------

WKV_CASES = [
    (2, 128, 2, 32, 32), (1, 96, 4, 64, 64), (2, 100, 2, 16, 32),
    (1, 33, 1, 64, 16),
]


@pytest.mark.parametrize("case", WKV_CASES,
                         ids=lambda c: f"B{c[0]}T{c[1]}H{c[2]}n{c[3]}bt{c[4]}")
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16],
                         ids=["f32", "bf16"])
def test_wkv6_sweep(case, dtype, pallas_interpret):
    B, T, H, n, bt = case
    ks = jax.random.split(KEY, 6)
    r = jax.random.normal(ks[0], (B, T, H, n), dtype)
    k = jax.random.normal(ks[1], (B, T, H, n), dtype) * 0.5
    v = jax.random.normal(ks[2], (B, T, H, n), dtype)
    logw = -jnp.exp(jax.random.normal(ks[3], (B, T, H, n)) * 0.5)
    u = jax.random.normal(ks[4], (H, n)) * 0.3
    S0 = jax.random.normal(ks[5], (B, H, n, n)) * 0.1
    y, S = wkv6_kernel(r, k, v, logw, u, S0, block_t=bt,
                       interpret=pallas_interpret)
    y_ref, S_ref = wkv6_ref(r, k, v, logw, u, S0)
    tol = 0.2 if dtype == jnp.bfloat16 else 5e-4
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_ref), atol=tol)
    np.testing.assert_allclose(np.asarray(S), np.asarray(S_ref), atol=5e-3)


def test_model_wkv_matches_oracle():
    """The in-model chunked WKV (models/rwkv.py) against the naive oracle."""
    from repro.models.rwkv import _wkv_chunked
    B, T, H, n = 2, 64, 2, 16
    ks = jax.random.split(KEY, 6)
    r = jax.random.normal(ks[0], (B, T, H, n))
    k = jax.random.normal(ks[1], (B, T, H, n)) * 0.5
    v = jax.random.normal(ks[2], (B, T, H, n))
    logw = -jnp.exp(jax.random.normal(ks[3], (B, T, H, n)) * 0.5)
    u = jax.random.normal(ks[4], (H, n)) * 0.3
    S0 = jax.random.normal(ks[5], (B, H, n, n)) * 0.1
    y_model, S_model = _wkv_chunked(r, k, v, logw, u, S0, chunk=16,
                                    unroll=False)
    y_ref, S_ref = wkv6_ref(r, k, v, logw, u, S0)
    np.testing.assert_allclose(np.asarray(y_model), np.asarray(y_ref),
                               atol=5e-4)
    np.testing.assert_allclose(np.asarray(S_model), np.asarray(S_ref),
                               atol=5e-3)


# ---------------------------------------------------------------------------
# mamba selective scan
# ---------------------------------------------------------------------------

SCAN_CASES = [
    (2, 128, 128, 16, 32, 128), (1, 100, 256, 8, 64, 128),
    (2, 64, 128, 16, 16, 64), (1, 37, 128, 4, 32, 128),
]


@pytest.mark.parametrize(
    "case", SCAN_CASES,
    ids=lambda c: f"B{c[0]}S{c[1]}I{c[2]}N{c[3]}bs{c[4]}bi{c[5]}")
def test_selective_scan_sweep(case, pallas_interpret):
    B, S, I, N, bs, bi = case
    ks = jax.random.split(KEY, 4)
    dA = jax.nn.sigmoid(jax.random.normal(ks[0], (B, S, I, N)))  # (0,1)
    dBu = jax.random.normal(ks[1], (B, S, I, N)) * 0.3
    C = jax.random.normal(ks[2], (B, S, N))
    h0 = jax.random.normal(ks[3], (B, I, N)) * 0.1
    y, h = selective_scan_kernel(dA, dBu, C, h0, block_s=bs, block_i=bi,
                                 interpret=pallas_interpret)
    y_ref, h_ref = selective_scan_ref(dA, dBu, C, h0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=1e-4)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_ref), atol=1e-4)


def test_model_mamba_chunk_matches_oracle():
    """models/mamba.py's associative-scan chunking vs the naive oracle."""
    from repro.models.mamba import _chunk_scan
    B, S, I, N = 2, 32, 8, 4
    ks = jax.random.split(KEY, 3)
    dA = jax.nn.sigmoid(jax.random.normal(ks[0], (B, S, I, N)))
    dBu = jax.random.normal(ks[1], (B, S, I, N)) * 0.3
    h0 = jax.random.normal(ks[2], (B, I, N)) * 0.1
    h_chunk = _chunk_scan(dA, dBu, h0)                 # (B, S, I, N)
    # oracle: stepwise
    C_dummy = jnp.ones((B, S, N))
    _, h_ref = selective_scan_ref(dA, dBu, C_dummy, h0)
    np.testing.assert_allclose(np.asarray(h_chunk[:, -1]), np.asarray(h_ref),
                               atol=1e-4)


# ---------------------------------------------------------------------------
# moe grouped matmul
# ---------------------------------------------------------------------------

GMM_CASES = [
    (4, 64, 128, 256, (32, 64, 64)), (8, 40, 64, 96, (16, 32, 32)),
    (2, 128, 96, 64, (64, 64, 32)), (16, 8, 32, 32, (8, 32, 32)),
]


@pytest.mark.parametrize(
    "case", GMM_CASES,
    ids=lambda c: f"E{c[0]}C{c[1]}D{c[2]}F{c[3]}")
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16],
                         ids=["f32", "bf16"])
def test_grouped_matmul_sweep(case, dtype, pallas_interpret):
    E, C, D, F, (bc, bf, bd) = case
    ks = jax.random.split(KEY, 2)
    x = jax.random.normal(ks[0], (E, C, D), dtype)
    w = jax.random.normal(ks[1], (E, D, F), dtype)
    out = grouped_matmul_kernel(x, w, block_c=bc, block_f=bf, block_d=bd,
                                interpret=pallas_interpret)
    ref = grouped_matmul_ref(x, w)
    tol = 0.5 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol)
