"""Fig. 5 latency-model calibration + Table 1 mapping (paper §5)."""
import pytest

from repro.core.latency import (
    CONFIG_PRIMITIVES, DEVICE, HOST, LATENCY_NS, TABLE1, UNAVAILABLE,
    available_primitives, primitive_latency, table1_row, trace_cost,
)


def test_host_local_remote_ratio():
    """Paper: CPU local Read/MStore 2.34x faster than remote."""
    assert LATENCY_NS[(HOST, "load", "remote")] == pytest.approx(
        2.34 * LATENCY_NS[(HOST, "load", "local")])
    assert LATENCY_NS[(HOST, "mstore", "remote")] == pytest.approx(
        2.34 * LATENCY_NS[(HOST, "mstore", "local")])


def test_device_local_remote_ratio():
    assert LATENCY_NS[(DEVICE, "load", "remote")] == pytest.approx(
        1.94 * LATENCY_NS[(DEVICE, "load", "local")])


def test_device_store_hierarchy():
    """Device→HM: LStore < RStore (2.08x) < MStore (1.45x over RStore)."""
    ls = LATENCY_NS[(DEVICE, "lstore", "remote")]
    rs = LATENCY_NS[(DEVICE, "rstore", "remote")]
    ms = LATENCY_NS[(DEVICE, "mstore", "remote")]
    assert rs == pytest.approx(2.08 * ls)
    assert ms == pytest.approx(1.45 * rs)
    assert ls < rs < ms


def test_rflush_priced_like_mstore():
    for node in (HOST, DEVICE):
        for loc in ("local", "remote"):
            assert LATENCY_NS[(node, "rflush", loc)] == pytest.approx(
                LATENCY_NS[(node, "mstore", loc)])


def test_host_device_remote_parity():
    """Paper: host and device remote accesses yield ~the same latency."""
    h = LATENCY_NS[(HOST, "load", "remote")]
    d = LATENCY_NS[(DEVICE, "load", "remote")]
    assert abs(h - d) / max(h, d) < 0.65   # same order; exact parity is chart noise


def test_unavailable_primitives_match_table1():
    """Paper: RStore/LFlush unavailable on host; LFlush unavailable on
    device (???)."""
    host_avail = available_primitives(HOST)
    dev_avail = available_primitives(DEVICE)
    assert "rstore" not in host_avail and "lflush" not in host_avail
    assert "lflush" not in dev_avail
    assert "rstore" in dev_avail
    assert table1_row("rstore", HOST).operation == UNAVAILABLE


def test_table1_shape():
    assert len(TABLE1) == 12           # 6 primitives x 2 nodes
    assert table1_row("mstore", HOST).operation.startswith("Non-Temporal")
    assert "ItoMWr" in table1_row("rstore", DEVICE).to_hm


def test_trace_cost_flit_cheaper_than_mstore_all():
    """Alg. 2 (LStore + one RFlush per op) must beat MStore-everything for
    multi-store operations — the paper's §6.1 performance argument."""
    # a high-level op doing 4 stores then one persist point, on the device,
    # targeting remote (HM) memory
    flit = [(DEVICE, "lstore", "remote")] * 4 + [(DEVICE, "rflush", "remote")]
    mstore = [(DEVICE, "mstore", "remote")] * 4
    assert trace_cost(flit) < trace_cost(mstore)


def test_config_primitive_restrictions():
    """§4: partitioned pool excludes RStore; non-coherent pool only allows
    memory-direct operations."""
    assert "rstore" not in CONFIG_PRIMITIVES["partitioned_pool"][HOST]
    nc = CONFIG_PRIMITIVES["shared_pool_noncoherent"][HOST]
    assert set(nc) == {"load_m", "mstore", "m-rmw"}
