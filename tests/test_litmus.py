"""Paper §3.4 Fig. 3 litmus tests + §3.5 variant tests + §6 motivating
example, checked against the executable CXL0 semantics."""
import pytest

from repro.core.litmus import LITMUS_TESTS, run_litmus
from repro.core.semantics import Variant


@pytest.mark.parametrize("variant", list(Variant), ids=lambda v: v.value)
@pytest.mark.parametrize("test", LITMUS_TESTS, ids=lambda t: t.name)
def test_litmus(test, variant):
    allowed = run_litmus(test, variant)
    assert allowed == test.expected[variant], (
        f"{test.name} under {variant.value}: model says "
        f"{'allowed' if allowed else 'illegal'}, paper says "
        f"{'allowed' if test.expected[variant] else 'illegal'}\n"
        f"{test.description}")


def test_variant_triples_match_paper_table():
    """§3.5 reports (CXL0, CXL0^LWB, CXL0^PSN) verdict triples for 10-12."""
    table = {
        "test10_variants": (True, False, True),
        "test11_variants": (True, False, True),
        "test12_variants": (True, True, False),
    }
    by_name = {t.name: t for t in LITMUS_TESTS}
    for name, (base, lwb, psn) in table.items():
        t = by_name[name]
        assert run_litmus(t, Variant.BASE) == base
        assert run_litmus(t, Variant.LWB) == lwb
        assert run_litmus(t, Variant.PSN) == psn
