"""Device-local sharded commit vs host-gather commit: bit-identical pool
state, opposite D2H traffic shape, format-compatible recovery.

The committer's device-sharded mode (``CXL0Config.mesh``) must be a pure
TRANSPORT change: each shard pipeline drains its devices' buffers
directly instead of a full-tree host gather, but the bytes that land in
the pool — shard files, CRCs, manifests — are identical to the classic
path at the same shard count.  That makes recovery trivially
cross-format, which is asserted in BOTH directions here.

Runs on the 8 host devices forced by conftest.py (``host_devices_8``
skips when a backend initialised first).
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.dsm.api import CXL0Config


def _mesh(shape=(2, 4)):
    return jax.make_mesh(shape, ("data", "model")[:len(shape)])


def _tree(n_leaves=6, dim=64, seed=0):
    key = jax.random.PRNGKey(seed)
    tree = {}
    for t in range(n_leaves):
        key, k = jax.random.split(key)
        tree[f"w{t}"] = jax.random.normal(k, (dim, dim), jnp.float32)
    return tree


def _shard(tree, mesh):
    sh = jax.sharding.NamedSharding(
        mesh, jax.sharding.PartitionSpec("data", "model"))
    return jax.tree_util.tree_map(lambda l: jax.device_put(l, sh), tree)


def _np_tree(tree):
    return {k: np.asarray(v) for k, v in tree.items()}


def _commit(path, tree, *, mesh=None, n_shards=4, topology=None):
    ctx = CXL0Config(path=str(path), schedule="sharded", n_shards=n_shards,
                     topology=topology, mesh=mesh).open()
    ctx.put({"params": tree}, step=1)
    with ctx.commit(1):
        pass
    return ctx


def _assert_trees_equal(a, b):
    la = jax.tree_util.tree_leaves(a)
    lb = jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_device_local_commit_bit_identical(host_devices_8, tmp_path):
    mesh = _mesh()
    tree = _shard(_tree(), mesh)
    expect = _np_tree(tree)

    ctx_dev = _commit(tmp_path / "dev", tree, mesh=mesh)
    ctx_hg = _commit(tmp_path / "hg", tree, mesh=None)

    # the D2H accounting proves the transport really differed: the device
    # path never gathered the full tree, the classic path ONLY did
    assert ctx_dev.tiers.d2h_gather_bytes == 0
    assert ctx_dev.tiers.d2h_shard_bytes > 0
    assert ctx_hg.tiers.d2h_gather_bytes > 0
    assert ctx_hg.tiers.d2h_shard_bytes == 0

    # ...while the durable state is indistinguishable
    assert ctx_dev.pool.latest_manifest() == ctx_hg.pool.latest_manifest()


def test_cross_format_recovery_both_directions(host_devices_8, tmp_path):
    mesh = _mesh()
    tree = _shard(_tree(seed=3), mesh)
    expect = _np_tree(tree)
    templates = {"params": _np_tree(tree)}

    _commit(tmp_path / "dev", tree, mesh=mesh)
    _commit(tmp_path / "hg", tree, mesh=None)

    # device-written pool read back by a mesh-less stack
    objs, step, src = CXL0Config(path=str(tmp_path / "dev")).open() \
        .recover(templates)
    assert (step, src) == (1, "pool")
    _assert_trees_equal(objs["params"], expect)

    # host-gather-written pool read back by a mesh-configured stack
    objs, step, src = CXL0Config(path=str(tmp_path / "hg"),
                                 mesh=mesh).open().recover(templates)
    assert (step, src) == (1, "pool")
    _assert_trees_equal(objs["params"], expect)


def test_shard_count_derived_from_mesh(host_devices_8, tmp_path):
    # 8 x 1 MiB leaves: the byte term allows 8 pipelines, so the device
    # term decides — a 2x2 sub-mesh must size to ITS 4 devices, not the
    # process's 8
    mesh = _mesh((2, 2))
    tree = _shard(_tree(n_leaves=8, dim=512, seed=1), mesh)
    ctx = _commit(tmp_path / "m22", tree, mesh=mesh, n_shards=None)
    assert ctx.committer.n_shards == 4

    ctx_hg = _commit(tmp_path / "flat", _np_tree(tree), n_shards=None)
    assert ctx_hg.committer.n_shards == 8  # local-device heuristic


def test_per_device_pricing_logged(host_devices_8, tmp_path):
    mesh = _mesh()
    tree = _shard(_tree(seed=2), mesh)
    ctx = _commit(tmp_path / "priced", tree, mesh=mesh, n_shards=None,
                  topology="cxl20-switched-pool")
    decisions = ctx.placement.decisions_for("shards")
    assert decisions, "sharded commit under a topology must price shards"
    d = decisions[-1]
    assert ctx.committer.n_shards == d.choice
    assert d.costs[f"k{d.choice}"] == min(d.costs.values())
    # priced from real per-device loads, committed device-local
    assert ctx.tiers.d2h_gather_bytes == 0
