"""MoE expert parallelism: the shard_map a2a and psum paths must agree with
the dense oracle. Runs on an 8-device mesh in a subprocess; the host
device force is inherited from the environment (set in conftest.py)."""
import json
import os
import subprocess
import sys
import textwrap

SCRIPT = textwrap.dedent("""
    import json
    import numpy as np
    import jax, jax.numpy as jnp
    from repro.configs import get_smoke_config
    from repro.models import moe as moe_mod
    from repro.models.params import init_params
    from repro.parallel.sharding import ctx_for_mesh

    cfg = get_smoke_config("olmoe-1b-7b")      # 8 experts top-2 (smoke)
    key = jax.random.PRNGKey(0)
    p = init_params(moe_mod.moe_descs(cfg), key, cfg.param_dtype)

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    ctx = ctx_for_mesh(mesh)
    B, S, D = 4, 8, cfg.d_model
    x = jax.random.normal(jax.random.fold_in(key, 1), (B, S, D),
                          jnp.bfloat16)

    y_dense, aux_dense = moe_mod.moe_forward(cfg, p, x, parallel=None)
    with jax.sharding.use_mesh(mesh) if hasattr(jax.sharding, "use_mesh") \
            else mesh:
        y_a2a, aux_a2a = jax.jit(
            lambda p, x: moe_mod.moe_forward(cfg, p, x, parallel=ctx,
                                             mode="a2a"))(p, x)
        y_psum, aux_psum = jax.jit(
            lambda p, x: moe_mod.moe_forward(cfg, p, x, parallel=ctx,
                                             mode="psum"))(p, x)

    e_a2a = float(jnp.max(jnp.abs(y_a2a.astype(jnp.float32)
                                  - y_dense.astype(jnp.float32))))
    e_psum = float(jnp.max(jnp.abs(y_psum.astype(jnp.float32)
                                   - y_dense.astype(jnp.float32))))
    print(json.dumps({"e_a2a": e_a2a, "e_psum": e_psum,
                      "aux_dense": float(aux_dense),
                      "aux_a2a": float(aux_a2a),
                      "aux_psum": float(aux_psum)}))
""")


def test_moe_ep_modes_match_dense(tmp_path):
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    proc = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-3000:]
    out = json.loads(proc.stdout.strip().splitlines()[-1])
    # NOTE on tolerance: the a2a path routes each device's token slice
    # LOCALLY (per-slice capacity) vs the oracle's global capacity — token
    # drop patterns can differ at the margin; values must still be close.
    assert out["e_a2a"] < 0.25, out
    assert out["e_psum"] < 0.05, out
    assert abs(out["aux_a2a"] - out["aux_dense"]) < 0.3
