"""Multi-writer pool safety: two committers sharing one pool must NEVER
lose or overwrite a completed commit — the original bug trusted the
init-time cached ``_manifest_seq``, so a restarted or concurrent
committer silently clobbered an existing ``manifest.<n>.json``.

Covers: a REAL two-process race, a property test over interleavings of
two committer handles, the stale-handle restart case, nested (``w<i>/``)
namespaces, and gc's empty-directory removal."""
import json
import os
import subprocess
import sys

import jax.numpy as jnp
import numpy as np
import pytest

from repro.dsm.pool import DSMPool

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))

_COMMIT_LOOP = """
import json, sys
from repro.dsm.pool import DSMPool
writer, n, path = sys.argv[1], int(sys.argv[2]), sys.argv[3]
pool = DSMPool(path)
obj = pool.write_object(f"w{writer}/x", 1, {"a": [1.0, 2.0]})
seqs = []
for i in range(n):
    seqs.append(pool.commit_manifest(
        i, {f"w{writer}/x": obj}, meta={"writer": writer, "i": i}))
print(json.dumps(seqs))
"""


def test_two_processes_never_overwrite_a_commit(tmp_path):
    """Two concurrent committer PROCESSES: every commit of both remains
    present and readable; no sequence number is ever reused."""
    env = dict(os.environ, PYTHONPATH=SRC + os.pathsep
               + os.environ.get("PYTHONPATH", ""))
    n = 25
    procs = [subprocess.Popen(
        [sys.executable, "-c", _COMMIT_LOOP, w, str(n), str(tmp_path)],
        env=env, stdout=subprocess.PIPE, stderr=subprocess.PIPE,
        text=True) for w in ("A", "B")]
    seqs = {}
    for w, p in zip("AB", procs):
        out, err = p.communicate(timeout=300)
        assert p.returncode == 0, err[-2000:]
        seqs[w] = json.loads(out.strip().splitlines()[-1])
    # no writer ever reused another's seq
    assert not set(seqs["A"]) & set(seqs["B"])
    ms = DSMPool(str(tmp_path)).manifests_desc()
    assert len(ms) == 2 * n                        # nothing lost
    assert len({m["seq"] for m in ms}) == 2 * n    # nothing overwritten
    # every commit of both writers is individually recoverable
    by_writer = {(m["meta"]["writer"], m["meta"]["i"]) for m in ms}
    assert by_writer == {(w, i) for w in "AB" for i in range(n)}


def test_stale_handle_restart_cannot_clobber(tmp_path):
    """The original bug: a handle opened BEFORE later commits cached a
    stale _manifest_seq and os.replace'd over an existing manifest."""
    pool_a = DSMPool(str(tmp_path))
    stale = DSMPool(str(tmp_path))        # caches seq = -1 now
    o = pool_a.write_object("x", 1, {"a": jnp.zeros(3)})
    committed = [pool_a.commit_manifest(i, {"x": o}, meta={"w": "a", "i": i})
                 for i in range(5)]
    s = stale.commit_manifest(99, {"x": o}, meta={"w": "stale"})
    assert s not in committed
    ms = DSMPool(str(tmp_path)).manifests_desc()
    assert len(ms) == 6
    assert {m["meta"].get("w") for m in ms} == {"a", "stale"}


def _check_interleaving(pool_dir, schedule):
    """Run one interleaving of two committer handles and assert no commit
    was lost, re-sequenced, or content-clobbered."""
    handles = [DSMPool(pool_dir), DSMPool(pool_dir)]
    obj = handles[0].write_object("x", 1, {"a": [0.5]})
    counts = [0, 0]
    seq_of = {}
    for w in schedule:
        i = counts[w]
        seq_of[(w, i)] = handles[w].commit_manifest(
            len(seq_of), {"x": obj}, meta={"w": w, "i": i})
        counts[w] += 1
    ms = DSMPool(pool_dir).manifests_desc()
    assert len(ms) == len(schedule)                      # none lost
    assert len({m["seq"] for m in ms}) == len(schedule)  # none reused
    for m in ms:                         # content never cross-clobbered
        assert seq_of[(m["meta"]["w"], m["meta"]["i"])] == m["seq"]


def test_all_interleavings_of_length_6(tmp_path_factory):
    """Exhaustive sweep over EVERY interleaving of two committers making 6
    commits between them (runs with or without hypothesis)."""
    for bits in range(64):
        schedule = [(bits >> k) & 1 for k in range(6)]
        _check_interleaving(str(tmp_path_factory.mktemp("il")), schedule)


def test_interleaved_commits_property(tmp_path_factory):
    """Property test over interleavings: two committer HANDLES of one pool
    interleaved per schedule — after any interleaving every completed
    commit is present, uniquely sequenced, and its content intact."""
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=20, deadline=None)
    @given(st.lists(st.integers(0, 1), min_size=2, max_size=14))
    def run(schedule):
        _check_interleaving(str(tmp_path_factory.mktemp("mw")), schedule)

    run()


def test_manifests_desc_orders_by_step_then_seq(tmp_path):
    """With concurrent committers a straggler can rename an OLDER step's
    manifest after a newer step committed (higher seq, older step);
    recovery must still prefer the newest STEP."""
    pool = DSMPool(str(tmp_path))
    o = pool.write_object("x", 1, {"a": jnp.zeros(2)})
    pool.commit_manifest(7, {"x": o})
    pool.commit_manifest(3, {"x": o})     # late straggler, higher seq
    ms = pool.manifests_desc()
    assert [m["step"] for m in ms] == [7, 3]
    assert pool.latest_manifest()["step"] == 7


def test_namespaced_max_version_and_gc(tmp_path):
    """Nested ``w<i>/<name>`` objects: version seeding sees them and gc
    walks them (the flat listdir of the original code saw neither).
    An unreferenced version ABOVE the newest kept reference is presumed
    in flight (a concurrent writer's not-yet-committed flush) and kept;
    one below the watermark is garbage."""
    pool = DSMPool(str(tmp_path))
    tree = {"a": jnp.arange(4.0)}
    assert pool.max_version("w0/params") == 0
    pool.write_object("w0/params", 1, tree)      # unreferenced, stale
    o3 = pool.write_object("w0/params", 3, tree)
    pool.write_object("w0/params", 5, tree)      # unreferenced, in-flight
    assert pool.max_version("w0/params") == 5
    pool.commit_manifest(0, {"w0/params": o3})
    pool.gc(keep=1)
    back = pool.read_object("w0/params", 3, tree, expected_crc=o3.crc)
    assert np.array_equal(np.asarray(back["a"]), np.arange(4.0))
    files = os.listdir(os.path.join(str(tmp_path), "objects", "w0",
                                    "params"))
    assert not any(f.startswith("00000001") for f in files)  # stale: gone
    assert any(f.startswith("00000005") for f in files)  # in-flight: kept
    # once a later manifest references past it, the dead version falls
    # behind the watermark and is collected
    o6 = pool.write_object("w0/params", 6, tree)
    pool.commit_manifest(1, {"w0/params": o6})
    pool.gc(keep=1)
    files = os.listdir(os.path.join(str(tmp_path), "objects", "w0",
                                    "params"))
    assert not any(f.startswith("00000005") for f in files)


def test_gc_family_watermark_protects_inflight_plain_write(tmp_path):
    """The kept manifests may reference an object only in SHARDED form
    (w0/params.s<k>) while a concurrent committer's in-flight flush of
    the same object is PLAIN (w0/params) — one version counter, two
    spellings.  gc's in-flight watermark is per family, so the plain
    write newer than the sharded watermark must survive (this exact race
    deleted shrink-flushed objects under retention gc)."""
    from repro.dsm.pool import ShardedObject, shard_family
    assert shard_family("w0/params.s3") == "w0/params"
    assert shard_family("w0/params") == "w0/params"
    assert shard_family("kv/r1.spam") == "kv/r1.spam"
    pool = DSMPool(str(tmp_path))
    leaves = [np.arange(4, dtype=np.float32), np.ones(3, np.float32)]
    s0 = pool.write_object("w0/params.s0", 7, [leaves[0]])
    s1 = pool.write_object("w0/params.s1", 7, [leaves[1]])
    sharded = ShardedObject("w0/params", 7, s0.nbytes + s1.nbytes, 2,
                            [s0, s1], [[0], [1]])
    pool.commit_manifest(5, {"w0/params": sharded})
    # another committer's flush for the NEXT commit, manifest not yet up
    o8 = pool.write_object("w0/params", 8, {"a": leaves[0]})
    pool.gc(keep=1)
    pool.read_object("w0/params", 8, {"a": leaves[0]}, expected_crc=o8.crc)
    # the kept manifest's shards survived too
    pool.read_entry("w0/params", sharded.to_entry(), leaves)


def test_gc_removes_emptied_object_dirs(tmp_path):
    """Retiring an object (no retained manifest references it) must not
    leave its ``objects/<name>/`` directory behind forever."""
    pool = DSMPool(str(tmp_path))
    tree = {"a": jnp.zeros(4)}
    keep = pool.write_object("keep", 1, tree)
    retired = pool.write_object("kv/r1", 1, tree)
    pool.commit_manifest(0, {"keep": keep, "kv/r1": retired})
    pool.commit_manifest(1, {"keep": keep})       # kv/r1 retired
    pool.gc(keep=1)
    obj_dir = os.path.join(str(tmp_path), "objects")
    assert not os.path.exists(os.path.join(obj_dir, "kv"))
    assert os.path.isdir(os.path.join(obj_dir, "keep"))
    # dirs holding a live version are untouched and still readable
    pool.read_object("keep", 1, tree, expected_crc=keep.crc)


def test_dead_reservation_skipped_and_collected(tmp_path):
    """A committer that died between seq reservation and rename leaves an
    empty manifest file: readers skip it, later commits step past it, and
    gc eventually collects it."""
    pool = DSMPool(str(tmp_path))
    o = pool.write_object("x", 1, {"a": jnp.zeros(2)})
    pool.commit_manifest(0, {"x": o})
    # simulate the dead reservation for the next seq
    dead = os.path.join(str(tmp_path), "manifest.2.json")
    open(dead, "w").close()
    assert [m["step"] for m in pool.manifests_desc()] == [0]
    s = pool.commit_manifest(1, {"x": o})
    assert s > 2                                  # stepped past the corpse
    assert [m["step"] for m in pool.manifests_desc()] == [1, 0]
    pool.commit_manifest(2, {"x": o})
    pool.gc(keep=1)
    assert not os.path.exists(dead)
