"""Paged KV layout (repro.serve.paging + the paged SessionStore path):
allocator single-ownership, block-table round-trips, slice/assemble
bit-identity, and paged-engine equivalence against the legacy
whole-lane layout.

The allocator/table invariants also run as hypothesis properties in
tests/test_paging_props.py; the versions here are deterministic seeded
sweeps so the invariants are exercised even where hypothesis is not
installed.
"""
import json

import jax
import numpy as np
import pytest

from repro.dsm.pool import DSMPool
from repro.serve.paging import (BLOCK_TOKENS, BlockAllocator, BlockPager,
                                BlockRef, BlockTable, OutOfBlocksError,
                                STATE_BLOCK, cache_token_axes, prefix_hash)
from repro.serve.scheduler import Request, SlotScheduler
from repro.serve.trace import synthetic_trace, trace_t_max

TRACE_KW = dict(prompt_lens=(8, 12), new_tokens=(4, 8, 16), seed=3)


@pytest.fixture(scope="module")
def smoke():
    from repro.configs import get_smoke_config
    from repro.models.registry import build
    cfg = get_smoke_config("olmo-1b")
    trace = synthetic_trace(6, vocab_size=cfg.vocab_size, **TRACE_KW)
    t_max = trace_t_max(trace)
    bundle = build(cfg, dec_pos_len=t_max)
    params = bundle.init_params(jax.random.PRNGKey(0))
    return cfg, bundle, params, trace, t_max


def _filled_cache1(smoke, seed=1, plen=16):
    cfg, bundle, params, _, t_max = smoke
    toks = jax.random.randint(jax.random.PRNGKey(seed), (1, plen), 0,
                              cfg.vocab_size)
    _, st = bundle.prefill(params, {"tokens": toks},
                           bundle.init_caches(jax.random.PRNGKey(0), 1,
                                              t_max))
    return st.caches


# ---------------------------------------------------------------------------
# allocator: single ownership (no jax)
# ---------------------------------------------------------------------------

def test_allocator_never_double_assigns_seeded_sweep():
    """1000 random alloc/free/adopt ops: a frame id is owned by at most
    one holder at every step, frees return exactly what was taken."""
    rng = np.random.default_rng(0)
    a = BlockAllocator(24)
    held = set()
    for _ in range(1000):
        op = rng.integers(0, 3)
        if op == 0 and a.n_free:
            bid = a.alloc()
            assert bid not in held
            held.add(bid)
        elif op == 1 and held:
            bid = int(rng.choice(sorted(held)))
            a.free(bid)
            held.discard(bid)
        elif op == 2:
            bid = int(rng.integers(0, 24))
            if bid in held:
                with pytest.raises(OutOfBlocksError):
                    a.adopt(bid)
            else:
                a.adopt(bid)
                held.add(bid)
        assert a.allocated == frozenset(held)
        assert a.n_free == 24 - len(held)


def test_allocator_exhaustion_and_bad_ops():
    a = BlockAllocator(2)
    b1, b2 = a.alloc(), a.alloc()
    assert b1 != b2
    with pytest.raises(OutOfBlocksError):
        a.alloc()
    with pytest.raises(ValueError):
        a.free(99)                     # never assigned
    with pytest.raises(ValueError):
        a.adopt(5)                     # outside the pool
    a.free(b1)
    a.adopt(b1)                        # explicit re-claim of a freed id
    with pytest.raises(OutOfBlocksError):
        a.adopt(b1)


# ---------------------------------------------------------------------------
# block table round-trip
# ---------------------------------------------------------------------------

def _table():
    t = BlockTable()
    t.refs[0] = BlockRef(blk=0, bid=3, tokens=16, name="kv/r1/b0",
                         entry={"name": "kv/r1/b0", "version": 2,
                                "crc": 123})
    t.refs[1] = BlockRef(blk=1, bid=7, tokens=5, name="kv/r1/b1")
    t.refs[STATE_BLOCK] = BlockRef(blk=STATE_BLOCK, bid=9, tokens=0,
                                   name="kv/r1/state")
    return t


def test_block_table_meta_roundtrip_bit_identical():
    t = _table()
    back = BlockTable.from_meta(json.loads(json.dumps(t.to_meta())))
    assert back.to_meta() == t.to_meta()
    assert sorted(back.bids()) == sorted(t.bids())
    assert back.entries() == t.entries()
    assert back.refs[1].entry is None


def test_block_table_roundtrip_through_pool_manifest(tmp_path):
    """The table rides in manifest meta: through an actual manifest
    commit + read-back it must survive byte-identically (json-safe)."""
    pool = DSMPool(str(tmp_path))
    o = pool.write_object("x", 1, {"a": np.zeros(3, np.float32)})
    meta = {"kind": "serve", "tables": {"r1": _table().to_meta()}}
    pool.commit_manifest(0, {"x": o}, meta)
    m = DSMPool(str(tmp_path)).latest_manifest()
    back = BlockTable.from_meta(m["meta"]["tables"]["r1"])
    assert back.to_meta() == _table().to_meta()


# ---------------------------------------------------------------------------
# pager: slice / assemble
# ---------------------------------------------------------------------------

def test_cache_token_axes_match_leaf_count(smoke):
    _, bundle, _, _, t_max = smoke
    pager = BlockPager(bundle, t_max)
    assert pager.tok_idx, "attention arch must have seq_kv leaves"
    assert len(pager.tok_idx) + len(pager.state_idx) \
        == len(jax.tree_util.tree_leaves(cache_token_axes(bundle)))


@pytest.mark.parametrize("pos_frac", [0.3, 0.6, 1.0])
def test_slice_assemble_roundtrip_bit_identical(smoke, pos_frac):
    """Splitting a prefilled cache into blocks and reassembling them is
    the identity — including at pos == t_max (the edge block)."""
    _, bundle, _, _, t_max = smoke
    pager = BlockPager(bundle, t_max, block_tokens=8)
    pos = max(1, int(t_max * pos_frac))
    cache1 = _filled_cache1(smoke, plen=min(pos, 16))
    host = pager._host_leaves(cache1)
    blocks = {blk: pager.slice_block(host, blk)
              for blk in range(pager.n_blocks(t_max))}
    if pager.state_idx:
        blocks[STATE_BLOCK] = pager.slice_state(host)
    back = pager.assemble(blocks)
    fa = jax.tree_util.tree_leaves(cache1)
    fb = jax.tree_util.tree_leaves(back)
    for x, y in zip(fa, fb):
        assert np.asarray(x).tobytes() == np.asarray(y).tobytes()


def test_slice_dirty_skips_clean_full_blocks(smoke):
    _, bundle, _, _, t_max = smoke
    pager = BlockPager(bundle, t_max, block_tokens=8)
    cache1 = _filled_cache1(smoke, plen=16)
    table = BlockTable()
    dirty = pager.slice_dirty(cache1, 20, table)
    # pos 20, bt 8 -> blocks 0,1 full + block 2 partial (+ state if any)
    assert set(b for b in dirty if b != STATE_BLOCK) == {0, 1, 2}
    # mark 0 and 1 durable and full: only the growing tail stays dirty
    for blk in (0, 1):
        table.refs[blk] = BlockRef(blk=blk, bid=blk, tokens=8,
                                   name=f"kv/r/b{blk}",
                                   entry={"name": f"kv/r/b{blk}",
                                          "version": 1, "crc": 0})
    dirty = pager.slice_dirty(cache1, 20, table)
    assert set(b for b in dirty if b != STATE_BLOCK) == {2}
    # a partial durable block goes dirty again once the position grows
    table.refs[2] = BlockRef(blk=2, bid=2, tokens=4, name="kv/r/b2",
                             entry={"name": "kv/r/b2", "version": 1,
                                    "crc": 0})
    dirty = pager.slice_dirty(cache1, 21, table)
    assert set(b for b in dirty if b != STATE_BLOCK) == {2}


def test_prefix_hash_is_prefix_stable():
    a = prefix_hash("k", [1, 2, 3, 4], 4)
    assert prefix_hash("k", [1, 2, 3, 4], 4) == a
    assert prefix_hash("k", [1, 2, 3, 5], 4) != a
    assert prefix_hash("k2", [1, 2, 3, 4], 4) != a          # model identity
    assert prefix_hash("k", [1, 2, 3, 4], 2) != a           # block geometry


# ---------------------------------------------------------------------------
# scheduler: slots freed by MIGRATION keep FIFO fairness
# ---------------------------------------------------------------------------

def test_fifo_fairness_when_slots_free_via_migration():
    """A slot released by migration (not completion) admits the next
    pending request in arrival order, and the migrated-in session enters
    the TARGET's queue ahead of fresh requests (submit_front)."""
    s = SlotScheduler(2)
    reqs = [Request(f"r{i}", (1, 2, 3), 4) for i in range(5)]
    s.submit(reqs)
    s.admit()                                  # r0, r1 running
    s.release("r0")                            # migrated out, NOT done
    placed = s.admit()
    assert [r.rid for _, r in placed] == ["r2"]   # FIFO refill
    t = SlotScheduler(2)
    t.submit([Request("x0", (1,), 2), Request("x1", (1,), 2)])
    t.submit_front(Request("r0", (1, 2, 3), 4))   # migrated-in
    placed = t.admit()
    assert [r.rid for _, r in placed] == ["r0", "x0"]
    with pytest.raises(AssertionError):
        t.submit_front(Request("r0", (1, 2, 3), 4))   # dup rid


# ---------------------------------------------------------------------------
# paged engine: equivalence + recovery
# ---------------------------------------------------------------------------

def _build(smoke, tmp, **kw):
    from repro.serve.engine import ServeEngine
    from repro.serve.sessions import SessionStore
    _, bundle, params, _, t_max = smoke
    store = SessionStore(DSMPool(str(tmp)),
                         engine_id=kw.pop("engine_id", 0))
    return ServeEngine(bundle, params, n_slots=2, t_max=t_max,
                       store=store, commit_every=2, **kw)


def test_paged_engine_equivalent_to_legacy(smoke, tmp_path):
    _, _, _, trace, _ = smoke
    legacy = _build(smoke, tmp_path / "legacy", paged=False)
    r0 = legacy.run(trace)
    legacy.close()
    paged = _build(smoke, tmp_path / "paged", paged=True, block_tokens=8)
    r1 = paged.run(trace)
    paged.close()
    assert r1.outputs == r0.outputs
    assert (r1.decode_ticks, r1.prefills, r1.commits) \
        == (r0.decode_ticks, r0.prefills, r0.commits)


def test_paged_commit_is_o_blocks_touched(smoke, tmp_path):
    """The paged layout's whole point: a mid-stream commit flushes only
    the dirty tail blocks, while every clean block is carried by
    reference — the newest manifest still describes the full cache."""
    _, _, _, trace, _ = smoke
    eng = _build(smoke, tmp_path, paged=True, block_tokens=4)
    eng.submit(trace)
    for _ in range(10):
        eng.tick()
    eng.store.drain()
    ms = DSMPool(str(tmp_path)).manifests_desc()
    assert len(ms) >= 2
    newest, prev = ms[0], ms[1]
    tables = newest["meta"]["tables"]
    names = {b["name"] for t in tables.values() for b in t["blocks"]}
    assert names <= set(newest["objects"]), \
        "every table block must be referenced by its manifest"
    assert any(len(t["blocks"]) > 2 for t in tables.values()), \
        "trace too short for a multi-block session"
    # at least one clean block was CARRIED by reference, not re-flushed:
    # same (name, version) in two consecutive manifests
    carried = [n for n, e in newest["objects"].items()
               if prev["objects"].get(n, {}).get("version")
               == e["version"]]
    assert carried, "no clean block carried across commits"
    eng.close()


def test_paged_resume_bit_identical(smoke, tmp_path):
    _, _, _, trace, _ = smoke
    ref = _build(smoke, tmp_path / "ref", paged=True)
    r0 = ref.run(trace)
    ref.close()
    half = _build(smoke, tmp_path / "kill", paged=True)
    half.submit(trace)
    for _ in range(7):
        half.tick()
    half.store.drain()
    half.close()
    back = _build(smoke, tmp_path / "kill", paged=True)
    step = back.resume()
    assert step is not None
    res = back.run(trace)
    back.close()
    assert res.outputs == r0.outputs
    assert res.resumed_sessions > 0


def test_paged_recover_falls_back_on_torn_block(smoke, tmp_path):
    """Corrupting a block referenced ONLY by the newest paged manifest
    sends recovery to the previous manifest — a session table never
    pairs with torn bytes."""
    _, _, _, trace, _ = smoke
    eng = _build(smoke, tmp_path, paged=True)
    eng.submit(trace)
    for _ in range(9):
        eng.tick()
    eng.store.drain()
    eng.close()
    pool = DSMPool(str(tmp_path))
    manifests = pool.manifests_desc()
    assert len(manifests) >= 2
    newest, prev = manifests[0], manifests[1]
    meta = newest["meta"]
    # corrupt a freshly-flushed block of a RUNNING session — one the
    # recovery of the newest manifest must read and the previous
    # manifest does not reference
    victim = None
    for rid, s in meta["sessions"].items():
        if s["done"] or "migrated_to" in s or rid not in meta["tables"]:
            continue
        for b in meta["tables"][rid]["blocks"]:
            e = newest["objects"][b["name"]]
            if prev["objects"].get(b["name"]) != e:
                victim = (b["name"], e["version"])
                break
        if victim:
            break
    assert victim is not None, "no fresh flush in the newest commit"
    path = pool.payload_path(*victim)
    data = open(path, "rb").read()
    open(path, "wb").write(data[:max(1, len(data) // 2)])
    back = _build(smoke, tmp_path, paged=True)
    step = back.resume()
    assert step == prev["step"]
    back.close()


def test_prefix_reuse_skips_prefill_bit_identically(smoke, tmp_path):
    _, _, _, trace, _ = smoke
    shared = [Request(rid=f"a{i}", prompt=trace[0].prompt,
                      max_new_tokens=6) for i in range(3)]
    e1 = _build(smoke, tmp_path, paged=True, engine_id=1,
                prefix_reuse=True, prefix_key="t")
    r1 = e1.run(shared)
    e1.close()
    assert r1.prefills >= 1
    again = [Request(rid=f"b{i}", prompt=trace[0].prompt,
                     max_new_tokens=6) for i in range(3)]
    e2 = _build(smoke, tmp_path, paged=True, engine_id=2,
                prefix_reuse=True, prefix_key="t")
    r2 = e2.run(again)
    e2.close()
    assert r2.prefills == 0 and r2.prefix_hits == 3
    assert [r2.outputs[f"b{i}"] for i in range(3)] \
        == [r1.outputs[f"a{i}"] for i in range(3)]
