"""Property-based tests of the paging invariants (hypothesis).

Deterministic seeded equivalents of every property here run in
tests/test_paging.py, so the invariants stay covered on machines where
hypothesis is not installed.
"""
import json

import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property-based tests need hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.serve.paging import (BlockAllocator, BlockRef, BlockTable,
                                OutOfBlocksError, STATE_BLOCK)
from repro.serve.scheduler import Request, SlotScheduler


@settings(deadline=None, max_examples=60)
@given(st.integers(2, 32),
       st.lists(st.tuples(st.integers(0, 2), st.integers(0, 31)),
                max_size=200))
def test_allocator_single_ownership(n, ops):
    """alloc/free/adopt in any order never double-assign a frame and
    never lose one: owned ∪ free is always the whole pool."""
    a = BlockAllocator(n)
    held = set()
    for op, arg in ops:
        if op == 0:
            if held == set(range(n)):
                with pytest.raises(OutOfBlocksError):
                    a.alloc()
            else:
                bid = a.alloc()
                assert bid not in held
                held.add(bid)
        elif op == 1:
            bid = arg % n
            if bid in held:
                a.free(bid)
                held.discard(bid)
            else:
                with pytest.raises(ValueError):
                    a.free(bid)
        else:
            bid = arg % n
            if bid in held:
                with pytest.raises(OutOfBlocksError):
                    a.adopt(bid)
            else:
                a.adopt(bid)
                held.add(bid)
        assert a.allocated == frozenset(held)
        assert a.n_free == n - len(held)


_refs = st.lists(
    st.tuples(st.integers(0, 63), st.integers(0, 255), st.integers(0, 64),
              st.booleans()),
    max_size=12, unique_by=lambda t: t[0])


@settings(deadline=None, max_examples=60)
@given(_refs, st.booleans())
def test_block_table_roundtrip_bit_identical(rows, with_state):
    """to_meta -> json -> from_meta -> to_meta is the identity — the
    table rides in manifest meta, so a json round-trip IS a commit."""
    t = BlockTable()
    for blk, bid, tokens, durable in rows:
        t.refs[blk] = BlockRef(
            blk=blk, bid=bid, tokens=tokens, name=f"kv/r/b{blk}",
            entry={"name": f"kv/r/b{blk}", "version": bid + 1,
                   "crc": tokens} if durable else None)
    if with_state:
        t.refs[STATE_BLOCK] = BlockRef(blk=STATE_BLOCK, bid=999, tokens=0,
                                       name="kv/r/state")
    back = BlockTable.from_meta(json.loads(json.dumps(t.to_meta())))
    assert back.to_meta() == t.to_meta()
    assert sorted(back.bids()) == sorted(t.bids())
    assert back.entries() == t.entries()


@settings(deadline=None, max_examples=60)
@given(st.integers(1, 4), st.integers(1, 12),
       st.lists(st.integers(0, 3), max_size=24))
def test_fifo_fairness_when_slots_free_via_migration(n_slots, n_reqs,
                                                     moves):
    """Interleaving migrations (release without completion, re-entry via
    submit_front) with completions never lets a fresh request overtake an
    earlier one: first admissions are in submission order, and a
    migrated-in session is re-admitted before anything still pending."""
    s = SlotScheduler(n_slots)
    s.submit([Request(f"r{i}", (1,), 2) for i in range(n_reqs)])
    s.admit()
    out = []
    mi = 0
    while not s.done:
        running = list(s.running)
        if moves and mi < len(moves) and running:
            victim = running[moves[mi] % len(running)]
            mi += 1
            s.release(victim)                  # migrated out...
            pending_before = [r.rid for r in s.pending]
            s.submit_front(Request(victim, (1,), 2))   # ...and back in
            assert [r.rid for r in s.pending] \
                == [victim] + pending_before
            s.admit()
            continue
        for rid in running:
            out.append(rid)
            s.release(rid)
        s.admit()
    # every request ran exactly once, and FIRST admissions are in exact
    # submission order: a migrated re-entry (submit_front) is a rid that
    # was already admitted, so it can never let a fresh request overtake
    # an earlier one
    assert sorted(out) == sorted(f"r{i}" for i in range(n_reqs))
    first_seen = list(dict.fromkeys(s.admission_order))
    assert first_seen == [f"r{i}" for i in range(n_reqs)]
