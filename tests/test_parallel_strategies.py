"""Beyond-paper perf levers must preserve semantics:

* dp_only strategy == tp strategy == unsharded reference loss (8-dev mesh);
* fp8 KV cache keeps decode argmax (slightly looser logit tolerance).
"""
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models.registry import build

SCRIPT = textwrap.dedent("""
    import json
    import jax, jax.numpy as jnp
    from repro.configs import get_smoke_config
    from repro.models.registry import build
    from repro.parallel.sharding import ctx_for_mesh
    from repro.train.elastic import shardings_for

    cfg = get_smoke_config("olmo-1b")
    bundle = build(cfg)
    key = jax.random.PRNGKey(0)
    params = bundle.init_params(key)
    batch = {"tokens": jax.random.randint(key, (8, 32), 0, cfg.vocab_size)}

    ref, _ = bundle.loss(params, batch)          # no mesh

    mesh = jax.make_mesh((4, 2), ("data", "model"))
    outs = {}
    for strategy in ("tp", "dp_only"):
        ctx = ctx_for_mesh(mesh, strategy=strategy)
        p_sh = jax.tree_util.tree_map(
            jax.device_put, params, shardings_for(ctx, bundle.descs))
        loss, _ = jax.jit(lambda p, b: bundle.loss(p, b, ctx=ctx))(p_sh,
                                                                   batch)
        outs[strategy] = float(loss)
    print(json.dumps({"ref": float(ref[0]) if isinstance(ref, tuple)
                      else float(ref), "outs": outs}))
""")


def test_strategies_match_reference():
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    proc = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-3000:]
    out = json.loads(proc.stdout.strip().splitlines()[-1])
    for strategy, loss in out["outs"].items():
        assert abs(loss - out["ref"]) < 0.03, (strategy, loss, out["ref"])


def test_fp8_cache_decode_consistency():
    cfg = get_smoke_config("yi-34b").with_(cache_dtype="float8_e4m3fn")
    bundle = build(cfg, dec_pos_len=64)
    key = jax.random.PRNGKey(1)
    params = bundle.init_params(key)
    B, S, T_MAX = 2, 16, 32
    toks = jax.random.randint(key, (B, S + 1), 0, cfg.vocab_size)
    caches = bundle.init_caches(key, B, T_MAX)
    assert str(jax.tree_util.tree_leaves(caches)[0].dtype) == "float8_e4m3fn"
    logits_p, state = jax.jit(
        lambda p, b, c: bundle.prefill(p, b, c))(
            params, {"tokens": toks[:, :S]}, caches)
    logits_d, _ = jax.jit(lambda p, t, s: bundle.decode(p, t, s))(
        params, toks[:, S:S + 1], state)

    from repro.models import lm
    ref, _ = lm.forward(cfg, params, toks)
    ref = ref.astype(jnp.float32)
    # fp8 quantization of K/V: tolerate larger logit error, argmax must hold
    assert float(jnp.max(jnp.abs(
        logits_d.astype(jnp.float32) - ref[:, S]))) < 1.0
    match = float(jnp.mean(
        (jnp.argmax(logits_d, -1) == jnp.argmax(ref[:, S], -1))
        .astype(jnp.float32)))
    assert match >= 0.5, match
