"""GPipe pipeline: output must equal the sequential stage composition."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.parallel.pipeline import gpipe_forward, pipeline_bubble_fraction


@pytest.mark.skipif(jax.device_count() < 2,
                    reason="pipeline test needs >=2 devices "
                           "(run under XLA_FLAGS=--xla_force_host_platform"
                           "_device_count=8 in CI)")
def test_gpipe_matches_sequential():
    P_ = min(4, jax.device_count())
    mesh = jax.make_mesh((P_,), ("stage",))
    M, mb, d = 6, 2, 8
    key = jax.random.PRNGKey(0)
    stage_w = jax.random.normal(key, (P_, d, d)) * 0.3
    x = jax.random.normal(jax.random.fold_in(key, 1), (M, mb, d))

    def apply_fn(w, x):
        return jnp.tanh(x @ w)

    piped = gpipe_forward(apply_fn, mesh)
    y = piped({"w": stage_w}[next(iter({"w"}))] if False else stage_w, x)

    ref = x
    for p in range(P_):
        ref = jnp.tanh(ref @ stage_w[p])
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_bubble_fraction():
    assert pipeline_bubble_fraction(4, 12) == pytest.approx(3 / 15)
    assert pipeline_bubble_fraction(1, 8) == 0.0
