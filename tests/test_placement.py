"""Cost-driven placement (repro.dsm.placement): decisions must FLIP when
the emulated topology changes, be logged with their priced alternatives,
never lose to a fixed strategy, and actually steer the wired layers
(DurableCommitter shard count/schedule, TieredKVCache.spill_auto,
cluster rank staging)."""
import numpy as np
import pytest

from repro.dsm.emu import PRESETS
from repro.dsm.flit_runtime import DurableCommitter
from repro.dsm.placement import PlacementPolicy, plan_rank_staging
from repro.dsm.pool import DSMPool
from repro.dsm.tiers import TierManager

MB = 1 << 20


# ---------------------------------------------------------------------------
# decisions flip with the topology preset
# ---------------------------------------------------------------------------

def test_shard_count_flips_with_topology():
    """Direct-attach has one link (sharding is overhead); the switched
    pool and fabric fan out — for the same 64 MiB state the chosen shard
    count must strictly grow with the topology's link count."""
    ks = {name: PlacementPolicy(name).choose_shards(64 * MB)
          for name in PRESETS}
    assert ks["cxl11-direct"] == 1
    assert (ks["cxl11-direct"] < ks["cxl20-switched-pool"]
            < ks["cxl30-fabric"])
    assert ks["cxl30-fabric"] <= PRESETS["cxl30-fabric"].n_links


def test_shard_count_scales_with_size():
    p = PlacementPolicy("cxl30-fabric")
    assert p.choose_shards(4 << 10) == 1         # latency-dominated
    assert p.choose_shards(64 * MB) > 1          # bandwidth-dominated


def test_spill_tier_flips_with_topology():
    """A 1 MiB object: the direct-attach staging path (fast cache-to-cache,
    slow single pool link) prefers staging; the fabric (slow multi-hop
    staging, wide pool fan-out) prefers the pool."""
    assert PlacementPolicy("cxl11-direct").choose_spill("kv", MB) == "staging"
    assert PlacementPolicy("cxl30-fabric").choose_spill("kv", MB) == "pool"


def test_spill_tier_flips_with_size():
    p = PlacementPolicy("cxl30-fabric")
    assert p.choose_spill("small", 4 << 10) == "staging"
    assert p.choose_spill("large", 64 * MB) == "pool"


def test_schedule_flips_with_size():
    p = PlacementPolicy("cxl11-direct")
    assert p.choose_schedule(64 << 10) == "sync"
    assert p.choose_schedule(64 * MB) == "sharded-async"


def test_decisions_are_logged_with_costs():
    p = PlacementPolicy("cxl20-switched-pool")
    p.choose_spill("kv/r1", 2 * MB)
    p.choose_shards(2 * MB, "kv/r1")
    p.choose_schedule(2 * MB, "state")
    kinds = [d.kind for d in p.decisions]
    assert kinds == ["spill", "shards", "schedule"]
    spill = p.decisions_for("spill")[0]
    assert spill.name == "kv/r1" and spill.nbytes == 2 * MB
    assert set(spill.costs) == {"staging", "pool"}
    assert spill.costs[spill.choice] == min(spill.costs.values())
    assert spill.topology == "cxl20-switched-pool"
    sched = p.decisions_for("schedule")[0]
    assert sched.choice in ("sync", "sharded-async")
    assert "flush_ns" in sched.costs


def test_policy_never_loses_to_fixed_strategies():
    """The bench invariant at test scale: per-object argmin of the same
    cost model can never exceed either fixed strategy, on any preset."""
    rng = np.random.default_rng(42)
    sizes = [int(x) for x in np.exp(rng.uniform(np.log(4 << 10),
                                                np.log(64 * MB), 16))]
    mixed = 0
    for name in PRESETS:
        p = PlacementPolicy(name)
        staging = pool = policy = 0.0
        choices = set()
        for nb in sizes:
            c = p.spill_costs(nb)
            staging += c["staging"]
            pool += c["pool"]
            ch = p.choose_spill("o", nb)
            choices.add(ch)
            policy += c[ch]
        assert policy <= staging + 1e-9
        assert policy <= pool + 1e-9
        mixed += len(choices) == 2
    assert mixed >= 1       # somewhere the decisions mix -> strict win


# ---------------------------------------------------------------------------
# wiring: committer
# ---------------------------------------------------------------------------

def _state(nbytes):
    return {"params": {"w": np.zeros(nbytes // 4, np.float32)}}


def test_committer_resolves_shards_from_policy(tmp_path):
    p = PlacementPolicy("cxl30-fabric")
    tiers = TierManager(DSMPool(str(tmp_path / "pool")), 0)
    c = DurableCommitter(tiers, mode="sharded", placement=p)
    c.update(_state(8 * MB))
    st = c.commit(0)
    assert st.n_shards == p.choose_shards(8 * MB, log=False)
    assert st.n_shards > 1
    assert p.decisions_for("shards")          # the decision was logged
    assert tiers.pool.latest_manifest()["step"] == 0
    tiers.close()


def test_committer_auto_mode_resolves_schedule(tmp_path):
    p = PlacementPolicy("cxl11-direct")
    tiers = TierManager(DSMPool(str(tmp_path / "pool")), 0)
    c = DurableCommitter(tiers, mode="auto", placement=p)
    c.update(_state(64 << 10))               # small: policy says sync
    st = c.commit(0)
    assert c.mode == "sync"
    assert st is not None and st.step == 0
    assert p.decisions_for("schedule")[0].choice == "sync"
    tiers.close()

    p2 = PlacementPolicy("cxl11-direct")
    tiers2 = TierManager(DSMPool(str(tmp_path / "pool2")), 0)
    c2 = DurableCommitter(tiers2, mode="auto", placement=p2)
    c2.update(_state(64 * MB))               # large: overlap pays
    c2.commit(0)
    assert c2.mode == "sharded-async"
    c2.drain()
    tiers2.close()


def test_auto_mode_requires_policy(tmp_path):
    tiers = TierManager(DSMPool(str(tmp_path / "pool")), 0)
    with pytest.raises(AssertionError):
        DurableCommitter(tiers, mode="auto")
    tiers.close()


def test_durable_loop_with_placement_auto(tmp_path):
    """End to end through the training loop: commit_mode='auto' + a policy
    resolves to a real schedule, the run commits durably, and the final
    state matches the fixed-schedule reference bit for bit (placement
    trades latency, never correctness)."""
    from repro.data.pipeline import DataPipeline, SyntheticLMSource
    from repro.scenarios.worker import (make_toy_state, make_toy_step,
                                        state_digest)
    from repro.train.loop import run_durable_loop

    def pipe():
        return DataPipeline(SyntheticLMSource(1024), 4, 32)

    p = PlacementPolicy("cxl20-switched-pool")
    pool = DSMPool(str(tmp_path / "auto"))
    r = run_durable_loop(make_toy_step(), make_toy_state(), pipe(), pool,
                         n_steps=6, commit_every=2, commit_mode="auto",
                         placement=p)
    assert pool.latest_manifest()["step"] == 5
    assert p.decisions_for("schedule")           # the choice was priced
    r_ref = run_durable_loop(make_toy_step(), make_toy_state(), pipe(),
                             DSMPool(str(tmp_path / "ref")), n_steps=6,
                             commit_every=2, commit_mode="sync")
    assert state_digest(r.state) == state_digest(r_ref.state)


# ---------------------------------------------------------------------------
# wiring: cluster rank staging
# ---------------------------------------------------------------------------

def test_plan_rank_staging_flips_with_topology():
    """A 1 MiB rank partition: ring RStore-staging is worth it on the
    direct pair, dead weight on the fabric (pool fan-out + slow staging
    path) — and either way the decision lands in the log."""
    p_direct = PlacementPolicy("cxl11-direct")
    p_fabric = PlacementPolicy("cxl30-fabric")
    assert plan_rank_staging(p_direct, MB) is True
    assert plan_rank_staging(p_fabric, MB) is False
    assert p_direct.decisions_for("staging")[0].choice is True
    assert p_fabric.decisions_for("staging")[0].nbytes == MB


# ---------------------------------------------------------------------------
# wiring: kv-cache spill_auto (real bundle, both routes restorable)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def smoke_bundle():
    import jax
    from repro.configs import get_smoke_config
    from repro.models.registry import build
    cfg = get_smoke_config("olmo-1b")
    bundle = build(cfg, dec_pos_len=32)
    params = bundle.init_params(jax.random.PRNGKey(0))
    return bundle, params


def _filled_cache1(bundle, params):
    import jax
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 8), 0, 64)
    _, st = bundle.prefill(params, {"tokens": toks},
                           bundle.init_caches(jax.random.PRNGKey(0), 1, 32))
    return st.caches


def _tree_eq(a, b):
    import jax
    fa, ta = jax.tree_util.tree_flatten(a)
    fb, tb = jax.tree_util.tree_flatten(b)
    assert ta == tb
    for x, y in zip(fa, fb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_spill_auto_routes_by_policy_and_restores(smoke_bundle, tmp_path):
    from repro.serve.kvcache import TieredKVCache
    bundle, params = smoke_bundle
    c1 = _filled_cache1(bundle, params)

    # direct-attach: small caches go to staging (host tier + peer buffer)
    tiers = TierManager(DSMPool(str(tmp_path / "a")), 0)
    peer = TierManager(DSMPool(str(tmp_path / "peer")), 1)
    kv = TieredKVCache(bundle, 2, 32, tiers=tiers,
                       placement=PlacementPolicy("cxl11-direct"))
    info = kv.spill_auto("kv/s0", c1, peer=peer)
    assert info["tier"] == "staging"
    _tree_eq(kv.restore("kv/s0"), c1)
    # ...and the copy really reached the peer's buffer (survives our loss)
    assert "kv/s0" in peer.staging

    # fabric at the same size: forced pool preference via a policy whose
    # staging path is hopeless (replay dominates), exercising the durable
    # route end to end
    tiers2 = TierManager(DSMPool(str(tmp_path / "b")), 0)
    pol = PlacementPolicy("cxl30-fabric", p_peer_loss=1.0,
                          replay_ns_per_byte=1e3)
    kv2 = TieredKVCache(bundle, 2, 32, tiers=tiers2, placement=pol)
    info2 = kv2.spill_auto("kv/s0", c1)
    assert info2["tier"] == "pool" and "entry" in info2
    tiers2.ldiscard("kv/s0")             # force the pool read path
    _tree_eq(kv2.restore("kv/s0", entry=info2["entry"]), c1)
    decisions = pol.decisions_for("spill")
    assert decisions and decisions[0].choice == "pool"
    tiers.close()
    tiers2.close()
