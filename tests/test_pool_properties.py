"""Property-based tests for the persistent pool (round-trip exactness,
manifest monotonicity, GC safety)."""
import numpy as np
import jax.numpy as jnp
import pytest
pytest.importorskip("hypothesis", reason="property-based tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.dsm.pool import CorruptObjectError, DSMPool


arrays = st.lists(
    st.tuples(
        st.sampled_from(["float32", "bfloat16", "int32", "float16"]),
        st.lists(st.integers(1, 7), min_size=0, max_size=3)),
    min_size=1, max_size=5)


@settings(max_examples=25, deadline=None)
@given(arrays, st.integers(0, 2**31 - 1))
def test_roundtrip_exact(tmp_path_factory, specs, seed):
    pool = DSMPool(str(tmp_path_factory.mktemp("pool")))
    rng = np.random.default_rng(seed)
    tree = {}
    for i, (dt, shape) in enumerate(specs):
        a = rng.normal(size=shape).astype(np.float32)
        tree[f"a{i}"] = jnp.asarray(a).astype(jnp.dtype(dt))
    pool.write_object("obj", 1, tree)
    back = pool.read_object("obj", 1, tree)
    for k in tree:
        a, b = np.asarray(tree[k]), np.asarray(back[k])
        assert a.shape == b.shape and str(a.dtype) == str(b.dtype)
        assert a.tobytes() == b.tobytes(), (k, a.dtype)


def test_manifest_seq_monotonic(tmp_path):
    pool = DSMPool(str(tmp_path))
    o = pool.write_object("x", 1, {"a": jnp.zeros(3)})
    s1 = pool.commit_manifest(0, {"x": o})
    s2 = pool.commit_manifest(1, {"x": o})
    assert s2 > s1
    # a NEW pool handle continues the sequence (restart safety)
    pool2 = DSMPool(str(tmp_path))
    s3 = pool2.commit_manifest(2, {"x": o})
    assert s3 > s2
    assert pool2.latest_manifest()["step"] == 2


def test_truncated_file_detected(tmp_path):
    pool = DSMPool(str(tmp_path))
    tree = {"a": jnp.arange(1000, dtype=jnp.float32)}
    pool.write_object("x", 1, tree)
    path = pool.payload_path("x", 1)
    data = open(path, "rb").read()
    open(path, "wb").write(data[: len(data) // 2])
    with pytest.raises(CorruptObjectError):
        pool.read_object("x", 1, tree)


def test_gc_drops_only_unreferenced(tmp_path):
    pool = DSMPool(str(tmp_path))
    tree = {"a": jnp.zeros(4)}
    for v in range(5):
        o = pool.write_object("x", v, tree)
        pool.commit_manifest(v, {"x": o})
    pool.gc(keep=2)
    ms = pool.manifests_desc()
    assert [m["step"] for m in ms] == [4, 3]
    # the kept versions still read back
    for m in ms:
        pool.read_object("x", m["objects"]["x"]["version"], tree)
