"""Torn durable writes against the pool's defenses: a payload corrupted
AFTER its atomic rename (visible but wrong — the CXL shared-memory
failure mode) must be rejected by the CRC/zip validation path, and
recovery must fall back past the poisoned commit instead of adopting it.
Covers all three torn modes, sharded objects (one bad shard poisons the
whole object), and the spill-file staging area's meta/payload CRC guard."""
import os

import numpy as np
import pytest

from repro.dsm.cluster import FileStagingArea
from repro.dsm.faults import TORN_MODES, FaultyPool, TornSpec, corrupt_file
from repro.dsm.pool import CorruptObjectError, DSMPool
from repro.dsm.recovery import ColdStartError, RecoveryManager
from repro.dsm.tiers import TierManager


def _tree(seed: float):
    return {"w": np.full((6, 6), seed, np.float32),
            "b": np.arange(6, dtype=np.float32) + seed}


TPL = {"t": _tree(0.0)}


@pytest.mark.parametrize("mode", TORN_MODES)
def test_each_torn_mode_is_detected(tmp_path, mode):
    pool = DSMPool(str(tmp_path))
    pool.write_object("t", 1, _tree(1.0))
    corrupt_file(pool.payload_path("t", 1), mode)
    with pytest.raises(CorruptObjectError):
        pool.read_object("t", 1, _tree(0.0))


@pytest.mark.parametrize("mode", TORN_MODES)
def test_each_torn_mode_is_detected_legacy(tmp_path, mode):
    """Same guarantee for legacy ``.npz`` objects still in the pool."""
    pool = DSMPool(str(tmp_path))
    pool.write_object_legacy("t", 1, _tree(1.0))
    corrupt_file(pool.payload_path("t", 1), mode)
    with pytest.raises(CorruptObjectError):
        pool.read_object("t", 1, _tree(0.0))


@pytest.mark.parametrize("mode", TORN_MODES)
def test_recovery_falls_back_past_torn_commit(tmp_path, mode):
    pool = FaultyPool(str(tmp_path))
    good = pool.write_object("t", 1, _tree(1.0))
    pool.commit_manifest(0, {"t": good})
    pool.force_corrupt("t", 2, mode)
    bad = pool.write_object("t", 2, _tree(2.0))
    pool.commit_manifest(1, {"t": bad})
    assert pool.injected == [("t", 2, mode)]
    objs, step, source = RecoveryManager(pool).recover(TPL)
    assert (step, source) == (0, "pool")
    np.testing.assert_array_equal(objs["t"]["w"], _tree(1.0)["w"])


def test_all_commits_torn_means_cold_start(tmp_path):
    pool = FaultyPool(str(tmp_path), torn=TornSpec(rate=1.0))
    obj = pool.write_object("t", 1, _tree(1.0))
    pool.commit_manifest(0, {"t": obj})
    with pytest.raises(ColdStartError):
        RecoveryManager(pool).recover(TPL)


def test_one_torn_shard_poisons_the_whole_object(tmp_path):
    pool = FaultyPool(str(tmp_path))
    tiers = TierManager(pool, worker_id=0)
    try:
        tiers.lstore("t", _tree(1.0))
        pool.commit_manifest(0, {"t": tiers.rflush_sharded("t", 2)})
        tiers.lstore("t", _tree(2.0))
        # tear ONE shard of the newer commit after it fully landed
        pool.force_corrupt("t.s1", 2, "bitflip")
        pool.commit_manifest(1, {"t": tiers.rflush_sharded("t", 2)})
    finally:
        tiers.close()
    sharded_entry = pool.manifests_desc()[0]["objects"]["t"]
    with pytest.raises(CorruptObjectError):
        pool.read_entry("t", sharded_entry, _tree(0.0))
    objs, step, _ = RecoveryManager(pool).recover(TPL)
    assert step == 0
    np.testing.assert_array_equal(objs["t"]["b"], _tree(1.0)["b"])


def test_manifest_crc_guards_against_overwritten_payload(tmp_path):
    """The file+sidecar pair is internally consistent but describes
    DIFFERENT bytes than the manifest recorded: read_entry must reject."""
    pool = DSMPool(str(tmp_path))
    obj = pool.write_object("t", 1, _tree(1.0))
    pool.commit_manifest(0, {"t": obj})
    pool.write_object("t", 1, _tree(9.0))      # same version, new content
    entry = pool.manifests_desc()[0]["objects"]["t"]
    with pytest.raises(CorruptObjectError):
        pool.read_entry("t", entry, _tree(0.0))


def test_torn_spill_is_discarded_by_staging_view(tmp_path):
    area = FileStagingArea(str(tmp_path / "stage"))
    area.proxy(1).staging["w0/t"] = (5, _tree(3.0))
    corrupt_file(area.payload_path(1, "w0/t"), "truncate")
    assert area.view(1, {"w0/t": _tree(0.0)}).staging == {}


def test_mislabeled_spill_meta_payload_pair_is_discarded(tmp_path):
    """Writer died between the payload and meta renames: the meta on disk
    describes the PREVIOUS payload.  The CRC in the meta must catch it."""
    area = FileStagingArea(str(tmp_path / "stage"))
    buf = area.proxy(1).staging
    buf["w0/t"] = (5, _tree(3.0))
    base = os.path.join(area.area(1), "w0__t")
    old_meta = open(base + ".json").read()
    buf["w0/t"] = (6, _tree(4.0))              # new payload lands...
    with open(base + ".json", "w") as f:
        f.write(old_meta)                       # ...under the OLD meta
    assert area.view(1, {"w0/t": _tree(0.0)}).staging == {}
    # a consistent pair is of course adopted
    buf["w0/t"] = (7, _tree(5.0))
    view = area.view(1, {"w0/t": _tree(0.0)})
    assert view.staging["w0/t"][0] == 7
    np.testing.assert_array_equal(view.staging["w0/t"][1]["w"],
                                  _tree(5.0)["w"])


def test_recovery_prefers_pool_over_torn_staging(tmp_path):
    """Peer staging newer than the pool would normally win; torn, it must
    lose — recovery lands on the durable commit, never a mangled copy."""
    pool = FaultyPool(str(tmp_path / "pool"))
    obj = pool.write_object("t", 1, _tree(1.0))
    pool.commit_manifest(3, {"t": obj})
    area = FileStagingArea(str(tmp_path / "stage"))
    area.proxy(1).staging["t"] = (7, _tree(7.0))     # newer than step 3
    corrupt_file(area.payload_path(1, "t"), "zero")
    peer = area.view(1, TPL)
    objs, step, source = RecoveryManager(pool).recover(TPL, (peer,))
    assert (step, source) == (3, "pool")
    np.testing.assert_array_equal(objs["t"]["w"], _tree(1.0)["w"])
