"""Proposition 1 (paper §3.4): exhaustive verification over a bounded
universe (the stand-in for the paper's Rocq proofs)."""
import pytest

from repro.core.props import PROP1_ITEMS, check_prop1_item
from repro.core.explore import reachable
from repro.core.state import make_config, check_invariant

CFG = make_config(2, 1)                    # 2 machines, 1 location each


@pytest.fixture(scope="module")
def states():
    return reachable(CFG, values=(0, 1))


@pytest.mark.parametrize("item", PROP1_ITEMS, ids=lambda it: f"item{it.idx}")
def test_prop1(item, states):
    res = check_prop1_item(item, CFG, values=(0, 1), states=states)
    assert res.checked > 0
    assert res.ok, (f"Prop 1.{item.idx} ({item.name}) fails: "
                    f"{res.counterexample}")


def test_global_cache_invariant(states):
    # reachable() asserts the invariant on every visited state; double-check
    assert all(check_invariant(s) for s in states)
    assert len(states) > 50


def test_volatile_memory_resets():
    """Crash of a volatile machine resets its memory to the initial value."""
    from repro.core.semantics import MStore, Crash, Load
    from repro.core.explore import trace_feasible
    cfg = make_config(2, 1, volatile=(True, False))
    # even MStore does not survive on volatile memory
    assert trace_feasible(cfg, (MStore(0, 0, 1), Crash(0), Load(0, 0, 0)))
    assert not trace_feasible(cfg, (MStore(0, 0, 1), Crash(1), Load(0, 0, 0)))
