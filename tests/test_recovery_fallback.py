"""Recovery fallback path (dsm/recovery.py): a corrupt shard — payload OR
CRC sidecar — must fail validation of the WHOLE object and push recovery
back to the previous manifest; recovery never returns torn state."""
import json
import os

import numpy as np
import jax.numpy as jnp
import pytest

from repro.data.pipeline import DataPipeline, SyntheticLMSource
from repro.dsm.pool import CorruptObjectError, DSMPool
from repro.dsm.recovery import RecoveryManager
from repro.scenarios.worker import make_toy_state, make_toy_step
from repro.train.loop import run_durable_loop


@pytest.fixture()
def committed_pool(tmp_path):
    """A pool with several sharded-async commits + the recovery templates."""
    pool = DSMPool(str(tmp_path / "pool"))
    state = make_toy_state()
    run_durable_loop(make_toy_step(), state,
                     DataPipeline(SyntheticLMSource(1024), 4, 32), pool,
                     n_steps=8, commit_every=2, n_shards=4)
    templates = {"params": state.params, "opt_mu": state.opt.mu,
                 "opt_nu": state.opt.nu,
                 "counters": {"opt_step": state.opt.step, "rng": state.rng},
                 "pipeline": {"seed": np.int64(0), "step": np.int64(0)}}
    return pool, templates


def _newest_params_shard(pool):
    newest = pool.latest_manifest()
    entry = newest["objects"]["params"]
    assert entry["sharded"]
    return newest, entry, entry["shards"][1]


def test_corrupt_crc_sidecar_falls_back(committed_pool):
    """Bit-rot in the CRC SIDECAR (not the payload) must also invalidate
    the shard — the sidecar is part of the durable write protocol."""
    pool, templates = committed_pool
    newest, entry, shard = _newest_params_shard(pool)
    sidecar = pool._obj_path(shard["name"], shard["version"]) + ".crc"
    with open(sidecar) as f:
        meta = json.load(f)
    meta["crc"] ^= 0xDEADBEEF
    with open(sidecar, "w") as f:
        json.dump(meta, f)
    with pytest.raises(CorruptObjectError):
        pool.read_entry("params", entry, templates["params"])
    objs, rec_step, src = RecoveryManager(pool).recover(templates)
    assert src == "pool"
    assert rec_step < newest["step"]


def test_missing_shard_file_falls_back(committed_pool):
    """A shard file that vanished (torn write, disk loss) is a torn commit:
    recovery must land on the previous manifest."""
    pool, templates = committed_pool
    newest, entry, shard = _newest_params_shard(pool)
    os.unlink(pool._obj_path(shard["name"], shard["version"]) + ".npz")
    with pytest.raises(CorruptObjectError):
        pool.read_entry("params", entry, templates["params"])
    objs, rec_step, src = RecoveryManager(pool).recover(templates)
    assert src == "pool"
    assert rec_step == newest["step"] - 2       # the previous commit point


def test_unreadable_sidecar_falls_back(committed_pool):
    pool, templates = committed_pool
    newest, entry, shard = _newest_params_shard(pool)
    sidecar = pool._obj_path(shard["name"], shard["version"]) + ".crc"
    with open(sidecar, "w") as f:
        f.write("{not json")
    objs, rec_step, src = RecoveryManager(pool).recover(templates)
    assert src == "pool"
    assert rec_step < newest["step"]


def test_all_manifests_corrupt_is_cold_start(tmp_path):
    pool = DSMPool(str(tmp_path / "pool"))
    state = make_toy_state()
    run_durable_loop(make_toy_step(), state,
                     DataPipeline(SyntheticLMSource(1024), 4, 32), pool,
                     n_steps=2, commit_every=1, n_shards=2)
    templates = {"params": state.params, "opt_mu": state.opt.mu,
                 "opt_nu": state.opt.nu,
                 "counters": {"opt_step": state.opt.step, "rng": state.rng},
                 "pipeline": {"seed": np.int64(0), "step": np.int64(0)}}
    for name in os.listdir(pool.obj_dir):
        d = os.path.join(pool.obj_dir, name)
        for fn in os.listdir(d):
            if fn.endswith(".npz"):
                os.unlink(os.path.join(d, fn))
    with pytest.raises(RuntimeError):
        RecoveryManager(pool).recover(templates)
