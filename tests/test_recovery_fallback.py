"""Recovery fallback path (dsm/recovery.py): a corrupt shard — payload OR
validation metadata (frame header / legacy CRC sidecar) — must fail
validation of the WHOLE object and push recovery back to the previous
manifest; recovery never returns torn state.

The sidecar tests rewrite one committed shard in the legacy ``.npz`` +
``.crc`` format first: they double as backward-compat proof that a
manifest referencing PRE-format-change objects still validates (and still
rejects sidecar rot) through the same read path."""
import json
import os

import numpy as np
import jax.numpy as jnp
import pytest

from repro.data.pipeline import DataPipeline, SyntheticLMSource
from repro.dsm import stream
from repro.dsm.pool import CorruptObjectError, DSMPool
from repro.dsm.recovery import RecoveryManager
from repro.scenarios.worker import make_toy_state, make_toy_step
from repro.train.loop import run_durable_loop


@pytest.fixture()
def committed_pool(tmp_path):
    """A pool with several sharded-async commits + the recovery templates."""
    pool = DSMPool(str(tmp_path / "pool"))
    state = make_toy_state()
    run_durable_loop(make_toy_step(), state,
                     DataPipeline(SyntheticLMSource(1024), 4, 32), pool,
                     n_steps=8, commit_every=2, n_shards=4)
    templates = {"params": state.params, "opt_mu": state.opt.mu,
                 "opt_nu": state.opt.nu,
                 "counters": {"opt_step": state.opt.step, "rng": state.rng},
                 "pipeline": {"seed": np.int64(0), "step": np.int64(0)}}
    return pool, templates


def _newest_params_shard(pool):
    newest = pool.latest_manifest()
    entry = newest["objects"]["params"]
    assert entry["sharded"]
    return newest, entry, entry["shards"][1]


def _legacyize_shard(pool, shard):
    """Rewrite one committed shard in the PR-6 ``.npz`` + ``.crc`` sidecar
    format — same leaves, so the object CRC (and thus the manifest) is
    unchanged.  The manifest now references a pre-format-change object,
    exactly the state of a pool mid rolling upgrade."""
    payload = pool.payload_path(shard["name"], shard["version"])
    arrays, crc, _ = stream.read_frame(payload)
    assert crc == shard["crc"]
    os.unlink(payload)
    pool.write_object_legacy(shard["name"], shard["version"], list(arrays))
    return pool._obj_path(shard["name"], shard["version"]) + ".crc"


def test_corrupt_crc_sidecar_falls_back(committed_pool):
    """Bit-rot in a LEGACY object's CRC sidecar (not the payload) must
    still invalidate the shard — the sidecar is part of the old durable
    write protocol, and old objects keep their full validation."""
    pool, templates = committed_pool
    newest, entry, shard = _newest_params_shard(pool)
    sidecar = _legacyize_shard(pool, shard)
    # first prove the legacy-format shard validates as-is (backward compat)
    pool.read_entry("params", entry, templates["params"])
    with open(sidecar) as f:
        meta = json.load(f)
    meta["crc"] ^= 0xDEADBEEF
    with open(sidecar, "w") as f:
        json.dump(meta, f)
    with pytest.raises(CorruptObjectError):
        pool.read_entry("params", entry, templates["params"])
    objs, rec_step, src = RecoveryManager(pool).recover(templates)
    assert src == "pool"
    assert rec_step < newest["step"]


def test_missing_shard_file_falls_back(committed_pool):
    """A shard file that vanished (torn write, disk loss) is a torn commit:
    recovery must land on the previous manifest."""
    pool, templates = committed_pool
    newest, entry, shard = _newest_params_shard(pool)
    os.unlink(pool.payload_path(shard["name"], shard["version"]))
    with pytest.raises(CorruptObjectError):
        pool.read_entry("params", entry, templates["params"])
    objs, rec_step, src = RecoveryManager(pool).recover(templates)
    assert src == "pool"
    assert rec_step == newest["step"] - 2       # the previous commit point


def test_unreadable_sidecar_falls_back(committed_pool):
    pool, templates = committed_pool
    newest, entry, shard = _newest_params_shard(pool)
    sidecar = _legacyize_shard(pool, shard)
    with open(sidecar, "w") as f:
        f.write("{not json")
    objs, rec_step, src = RecoveryManager(pool).recover(templates)
    assert src == "pool"
    assert rec_step < newest["step"]


def test_corrupt_frame_header_falls_back(committed_pool):
    """The streamed format's analog of sidecar rot: damage to the frame's
    embedded header (not the payload) must invalidate the shard."""
    pool, templates = committed_pool
    newest, entry, shard = _newest_params_shard(pool)
    payload = pool.payload_path(shard["name"], shard["version"])
    with open(payload, "r+b") as f:
        f.seek(18)                   # inside the header JSON
        b = f.read(1)
        f.seek(18)
        f.write(bytes([b[0] ^ 0xFF]))
    with pytest.raises(CorruptObjectError):
        pool.read_entry("params", entry, templates["params"])
    objs, rec_step, src = RecoveryManager(pool).recover(templates)
    assert src == "pool"
    assert rec_step < newest["step"]


def test_all_manifests_corrupt_is_cold_start(tmp_path):
    pool = DSMPool(str(tmp_path / "pool"))
    state = make_toy_state()
    run_durable_loop(make_toy_step(), state,
                     DataPipeline(SyntheticLMSource(1024), 4, 32), pool,
                     n_steps=2, commit_every=1, n_shards=2)
    templates = {"params": state.params, "opt_mu": state.opt.mu,
                 "opt_nu": state.opt.nu,
                 "counters": {"opt_step": state.opt.step, "rng": state.rng},
                 "pipeline": {"seed": np.int64(0), "step": np.int64(0)}}
    for name in os.listdir(pool.obj_dir):
        d = os.path.join(pool.obj_dir, name)
        for fn in os.listdir(d):
            if fn.endswith((".npz", stream.SUFFIX)):
                os.unlink(os.path.join(d, fn))
    with pytest.raises(RuntimeError):
        RecoveryManager(pool).recover(templates)
