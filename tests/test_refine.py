"""Variant trace-inclusion (paper §3.5): PSN/LWB refine BASE; PSN and LWB
are incomparable. Full language inclusion via subset construction (the FDR4
stand-in)."""
import pytest

from repro.core.refine import check_refinement
from repro.core.semantics import Variant
from repro.core.state import make_config

CFG = make_config(2, 1)


@pytest.mark.slow
def test_psn_refines_base():
    assert check_refinement(Variant.PSN, Variant.BASE, CFG).refines


@pytest.mark.slow
def test_lwb_refines_base():
    assert check_refinement(Variant.LWB, Variant.BASE, CFG).refines


def test_variants_incomparable():
    r1 = check_refinement(Variant.PSN, Variant.LWB, CFG)
    r2 = check_refinement(Variant.LWB, Variant.PSN, CFG)
    assert not r1.refines and not r2.refines
    # the witnesses are (relabelings of) the paper's litmus tests 10-12
    assert any("crash" in w for w in r1.witness)
    assert any("crash" in w for w in r2.witness)


def test_base_strictly_more_permissive():
    assert not check_refinement(Variant.BASE, Variant.LWB, CFG).refines
    assert not check_refinement(Variant.BASE, Variant.PSN, CFG).refines
