"""The elastic-autoscaling subsystem (repro.scale): deterministic
traffic generation, the grow-by-repartition pure helpers, the
cost-priced scale controller and its fleet simulation, the fuzzer's
``scale`` workload (a planned grow under adversarial kills), and one
cross-process joiner-kill cell through the real worker protocol."""
import json
import os

import numpy as np
import pytest

from repro.dsm.emu import get_topology, join_transfer_ns
from repro.dsm.faults import FaultSchedule, JOIN_POINTS, KillSpec
from repro.dsm.placement import PlacementPolicy
from repro.scale.autoscaler import (Autoscaler, AutoscaleConfig,
                                    simulate_autoscale, simulate_fixed)
from repro.scale.grow import join_moves, join_name, join_templates
from repro.scale.traffic import (TrafficConfig, arrival_counts,
                                 offered_tokens, traffic_trace)
from repro.scenarios.fuzz import (BREAK_ENV, EpisodeConfig, make_episode,
                                  run_episode)
from repro.serve.trace import synthetic_trace
from repro.train.elastic import partition_plan, plan_delta


# ---------------------------------------------------------------------------
# traffic: pure in (seed, config)
# ---------------------------------------------------------------------------

def test_arrival_counts_pure_in_seed_and_config():
    cfg = TrafficConfig(seed=7, horizon_ticks=64)
    a, b = arrival_counts(cfg), arrival_counts(cfg)
    assert np.array_equal(a, b) and a.shape == (64,) and a.dtype == np.int64
    assert not np.array_equal(a, arrival_counts(TrafficConfig(
        seed=8, horizon_ticks=64)))


def test_traffic_trace_deterministic_and_arrival_sorted():
    cfg = TrafficConfig(seed=3, horizon_ticks=48)
    t1, t2 = traffic_trace(cfg), traffic_trace(cfg)
    assert t1 == t2, "trace is not a pure function of (seed, config)"
    assert len(t1) == int(arrival_counts(cfg).sum())
    assert all(t1[i].arrival <= t1[i + 1].arrival
               for i in range(len(t1) - 1))
    assert offered_tokens(t1) == sum(r.max_new_tokens for r in t1) > 0


def test_diurnal_swing_shapes_the_day():
    """With bursts off, mid-day intensity must exceed the midnight
    trough — the sinusoid actually shapes the offered load."""
    cfg = TrafficConfig(seed=0, horizon_ticks=96, base_rate=4.0,
                        burst_rate=0.0)
    counts = arrival_counts(cfg)
    q = len(counts) // 4
    assert counts[q:3 * q].mean() > counts[:q].mean()


def test_synthetic_trace_arrivals_do_not_perturb_prompts():
    """The ``arrivals`` field rides along: same seed gives byte-identical
    prompts/budgets with or without it, and omitting it keeps the
    pre-existing default of everything arriving at tick 0."""
    base = synthetic_trace(6, seed=5, vocab_size=64)
    timed = synthetic_trace(6, seed=5, vocab_size=64,
                            arrivals=[0, 1, 1, 2, 3, 5])
    assert [r.arrival for r in base] == [0] * 6
    assert [r.arrival for r in timed] == [0, 1, 1, 2, 3, 5]
    assert [r.prompt for r in base] == [r.prompt for r in timed]
    assert [r.max_new_tokens for r in base] == \
        [r.max_new_tokens for r in timed]


# ---------------------------------------------------------------------------
# grow-by-repartition pure helpers
# ---------------------------------------------------------------------------

def test_join_moves_are_exactly_the_joiner_gains():
    names = [f"t{i}" for i in range(8)]
    old = partition_plan(names, [0, 1, 2])
    new = partition_plan(names, [0, 1, 2, 3])
    moves = join_moves(old, new, 3)
    assert moves, "a 3->4 grow over 8 entries moves something"
    for n, src in moves.items():
        assert old[n] == src and new[n] == 3
    # everything the delta re-homes to the joiner is in the move set
    assert set(moves) == {n for n, (_, dst) in
                          plan_delta(old, new).items() if dst == 3}
    tpl = join_templates(moves, dim=4)
    assert set(tpl) == {join_name(n) for n in moves}
    for v in tpl.values():
        assert set(v) == {"p", "mu", "nu"}
        assert v["p"].shape == (4, 4)


def test_every_process_derives_the_same_move_set():
    names = [f"t{i}" for i in range(11)]
    old = partition_plan(names, [0, 1, 2])
    new = partition_plan(names, [0, 1, 2, 3])
    # per-rank filtering of the shared move set partitions it exactly
    moves = join_moves(old, new, 3)
    per_rank = {r: {n for n, src in moves.items() if src == r}
                for r in (0, 1, 2)}
    assert set().union(*per_rank.values()) == set(moves)
    assert sum(len(v) for v in per_rank.values()) == len(moves)


# ---------------------------------------------------------------------------
# the cost-priced controller
# ---------------------------------------------------------------------------

def test_scale_costs_price_the_join_capital():
    pol = PlacementPolicy("cxl20-switched-pool")
    idle = pol.scale_costs(0, 2, 4, 1 << 20, session_ticks=16.0,
                           engine_tick_ns=1e6, max_engines=12)
    assert set(idle) >= {"hold", "grow", "shrink"}
    assert idle["hold"] < idle["grow"], \
        "an idle fleet must not pay join capital for nothing"
    deep = pol.scale_costs(64, 2, 4, 1 << 20, session_ticks=16.0,
                           engine_tick_ns=1e6, max_engines=12)
    assert deep["grow"] < deep["hold"], \
        "a deep queue must make the join capital pay for itself"


def test_choose_scale_logs_all_priced_alternatives():
    pol = PlacementPolicy("cxl20-switched-pool")
    choice = pol.choose_scale("fleet@t0", 64, 2, 4, 1 << 20,
                              session_ticks=16.0, engine_tick_ns=1e6,
                              max_engines=12)
    assert choice == "grow"
    scale_decisions = pol.decisions_for("scale")
    assert len(scale_decisions) == 1
    d = scale_decisions[0]
    assert d.choice == "grow" and set(d.costs) >= {"hold", "grow", "shrink"}


def test_join_capital_tracks_the_topology():
    """The decision flips per preset because the cost model does: the
    staged join transfer gets strictly pricier as the fabric deepens."""
    n = 1 << 20
    direct = join_transfer_ns(get_topology("cxl11-direct"), n)
    switched = join_transfer_ns(get_topology("cxl20-switched-pool"), n)
    fabric = join_transfer_ns(get_topology("cxl30-fabric"), n)
    assert direct < switched < fabric
    grow_costs = {t: PlacementPolicy(t).scale_costs(
        8, 2, 4, n, session_ticks=16.0, engine_tick_ns=1e6,
        max_engines=12)["grow"] for t in
        ("cxl11-direct", "cxl20-switched-pool", "cxl30-fabric")}
    assert grow_costs["cxl11-direct"] < grow_costs["cxl30-fabric"]


def test_autoscaler_cooldown_is_asymmetric():
    """Scale-out is never suppressed; scale-in honors the cooldown."""
    cfg = AutoscaleConfig(cooldown_ticks=16)
    sc = Autoscaler(cfg)
    assert sc.decide(0, queue_depth=64, n_engines=2) > 0
    # immediately after the grow, a burst still gets answered
    assert sc.decide(1, queue_depth=200, n_engines=4) > 0
    # ...but an idle lull inside the cooldown cannot shrink
    assert sc.decide(2, queue_depth=0, n_engines=8, busy_lanes=0) == 0
    assert sc.decide(1 + cfg.cooldown_ticks, queue_depth=0, n_engines=8,
                     busy_lanes=0) < 0


def test_autoscaler_respects_engine_bounds():
    cfg = AutoscaleConfig(min_engines=1, max_engines=4)
    sc = Autoscaler(cfg)
    d = sc.decide(0, queue_depth=10**6, n_engines=1)
    assert 1 + d <= cfg.max_engines
    sc2 = Autoscaler(AutoscaleConfig())
    assert sc2.join_delay_ticks() >= 1, "a join is never free"


# ---------------------------------------------------------------------------
# the simulated fleet: elasticity must pay
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("topology", ["cxl11-direct", "cxl20-switched-pool"])
def test_autoscaled_fleet_beats_best_fixed(topology):
    trace = traffic_trace(TrafficConfig(seed=3))
    cfg = AutoscaleConfig(topology=topology)
    auto = simulate_autoscale(trace, cfg)
    fixed = {n: simulate_fixed(trace, n, cfg)
             for n in range(1, cfg.max_engines + 1)}
    best = min(fixed.values(), key=lambda r: r.priced_cost_ns)
    assert auto.lost_sessions == 0 and auto.served == len(trace)
    assert auto.priced_cost_ns < best.priced_cost_ns
    assert auto.p99_admission_ticks < best.p99_admission_ticks
    assert auto.grows > 0, "the controller never scaled out"
    assert auto.engines_max > auto.engines_min, "capacity never moved"


def test_simulation_is_deterministic():
    trace = traffic_trace(TrafficConfig(seed=1, horizon_ticks=64))
    cfg = AutoscaleConfig()
    assert simulate_autoscale(trace, cfg) == simulate_autoscale(trace, cfg)
    assert simulate_fixed(trace, 3, cfg) == simulate_fixed(trace, 3, cfg)


def test_decision_log_dumps_every_priced_alternative(tmp_path):
    trace = traffic_trace(TrafficConfig(seed=3, horizon_ticks=64))
    cfg = AutoscaleConfig()
    scaler = Autoscaler(cfg)
    res = simulate_autoscale(trace, cfg, scaler=scaler)
    log = tmp_path / "decisions.jsonl"
    scaler.dump_decisions(str(log))
    lines = [json.loads(l) for l in log.read_text().splitlines()]
    assert len(lines) == res.decisions > 0
    for d in lines:
        assert d["kind"] == "scale"
        assert d["choice"] in d["costs"]
        # alternatives invalid at the boundary (shrink at min_engines,
        # grow at max) are not priced; hold always is, plus >=1 other
        assert "hold" in d["costs"] and len(d["costs"]) >= 2
        assert set(d["costs"]) <= {"hold", "grow", "shrink"}


# ---------------------------------------------------------------------------
# the fuzzer's scale workload: a planned grow under adversarial kills
# ---------------------------------------------------------------------------

def _scale_cfg(**kw):
    return EpisodeConfig(workload="scale", steps=8, commit_every=2,
                         n_tensors=4, grow_at=4, **kw)


def test_fuzz_scale_clean_episode_no_violations(tmp_path):
    res = run_episode(_scale_cfg(), FaultSchedule(), str(tmp_path))
    assert res.ok, res.violations
    assert res.recoveries, "the forced final crash still checks recovery"


@pytest.mark.parametrize("point", JOIN_POINTS)
def test_fuzz_scale_joiner_killed_at_join_window(point, tmp_path):
    cfg = _scale_cfg()
    sched = FaultSchedule(kills=(
        KillSpec(worker=cfg.world, point=point, at_step=cfg.grow_at - 1),))
    res = run_episode(cfg, sched, str(tmp_path))
    assert res.ok, res.violations
    # join_staged/join_committed fire pre-adoption (the joiner owns
    # nothing and the grow is abandoned); join_adopted fires after
    assert len(res.kills_fired) == 1


def test_fuzz_scale_old_rank_killed_mid_join(tmp_path):
    cfg = _scale_cfg()
    sched = FaultSchedule(kills=(
        KillSpec(worker=1, point="join_staged", at_step=cfg.grow_at - 1),))
    res = run_episode(cfg, sched, str(tmp_path))
    assert res.ok, res.violations
    assert len(res.kills_fired) == 1 and res.recoveries


def test_fuzz_scale_episode_is_bit_deterministic(tmp_path):
    cfg, sched = make_episode([0, 2, 3, 0], "scale", "cxl11-direct")
    r1 = run_episode(cfg, sched, str(tmp_path / "a"))
    r2 = run_episode(cfg, sched, str(tmp_path / "b"))
    assert r1.to_json() == r2.to_json()


def test_fuzz_scale_break_canary_is_caught(tmp_path, monkeypatch):
    monkeypatch.setenv(BREAK_ENV, "1")
    cfg = _scale_cfg()
    sched = FaultSchedule(kills=(
        KillSpec(worker=0, point="post_completeOp", at_step=5),))
    res = run_episode(cfg, sched, str(tmp_path))
    assert not res.ok, "stale-state swap at the seam went unnoticed"


# ---------------------------------------------------------------------------
# the real thing: in-process fleet cell + cross-process joiner kill
# ---------------------------------------------------------------------------

def test_fleet_grow_and_drain_is_invisible_in_tokens(tmp_path):
    from repro.scenarios.scale import run_fleet_scale_cell
    res = run_fleet_scale_cell(str(tmp_path))
    assert res.ok, res
    assert res.outputs_match and res.grew


def test_cross_process_joiner_kill_recovers_old_membership(tmp_path):
    """One kill cell through REAL worker processes: the joiner dies at
    the join-committed boundary, the survivors fall back to the old
    membership and finish bit-identical to a straight 3-rank run."""
    from repro.scenarios.scale import run_grow_scenario
    res = run_grow_scenario("join_committed", str(tmp_path),
                            steps=6, tensors=4, join_at=4)
    assert res.ok, (res.detail, res.lives, res.sources)
    assert res.killed and set(res.lives) == {(0, 1, 2)}
    assert res.digests == res.reference_digests
