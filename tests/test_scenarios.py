"""System-scale crash-injection scenarios (repro.scenarios): a REAL worker
process is os._exit-killed at each commit-window point, restarted, and must
recover to the newest completed commit with a final state bit-identical to
an uninterrupted run — durable linearizability verified end to end, not on
simulated histories."""
import pytest

from repro.dsm.flit_runtime import KILL_POINTS
from repro.scenarios.runner import reference_digest, run_scenario

STEPS = 8
COMMIT_EVERY = 2
SHARDS = 4


@pytest.fixture(scope="module")
def ref_digest(tmp_path_factory):
    """One uninterrupted reference run shared by all kill points."""
    return reference_digest(str(tmp_path_factory.mktemp("ref")),
                            steps=STEPS, commit_every=COMMIT_EVERY,
                            shards=SHARDS)


@pytest.mark.parametrize("point", KILL_POINTS)
def test_kill_point_recovers_completed_commit(point, tmp_path, ref_digest):
    res = run_scenario(point, str(tmp_path), steps=STEPS,
                       commit_every=COMMIT_EVERY, shards=SHARDS,
                       ref_digest=ref_digest)
    assert res.killed, res.detail
    # recovery landed on a COMPLETED commit — in fact the newest one
    assert res.recovered_completed_commit, res
    assert res.resumed_from == max(res.completed_steps_at_kill), res
    assert res.recovery_source == "pool"
    # crash + recover + replay is bit-identical to the uninterrupted run
    assert res.final_digest == res.reference_digest, res
    assert res.ok


def test_mid_flush_kill_leaves_torn_write_invisible(tmp_path, ref_digest):
    """The mid-flush kill leaves >= 1 shard of the dying commit durable but
    no manifest; that step must NOT appear in the completed set."""
    res = run_scenario("mid_flush", str(tmp_path), steps=STEPS,
                       commit_every=COMMIT_EVERY, shards=SHARDS,
                       ref_digest=ref_digest)
    assert res.killed, res.detail
    kill_step = 2 * COMMIT_EVERY - 1
    assert kill_step not in res.completed_steps_at_kill
    assert res.ok


def test_sync_schedule_scenario(tmp_path, ref_digest):
    """The kill harness also covers the blocking schedules (same contract:
    pre-flush kill -> the in-flight commit is simply not durable)."""
    res = run_scenario("pre_flush", str(tmp_path), steps=STEPS,
                       commit_every=COMMIT_EVERY, mode="sync", shards=1,
                       ref_digest=ref_digest)   # final state is
    #                     schedule-independent, so the reference is shared
    assert res.killed, res.detail
    assert res.recovered_completed_commit, res
    assert res.resumed_from == max(res.completed_steps_at_kill), res
    assert res.final_digest == res.reference_digest, res
