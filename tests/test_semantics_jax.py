"""The vectorized JAX semantics must agree with the Python reference LTS on
random schedules (same effective, eager-flush interpretation)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis", reason="property-based tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import state as cstate
from repro.core.semantics import (
    step_crash, step_faa, step_load, step_lstore, step_mstore, step_rstore,
    step_tau_cc, step_tau_cm,
)
from repro.core.semantics_jax import (
    ACT, BOT, JaxSystem, initial_arrays, random_schedules, run_schedule,
    run_schedules,
)

SYS = JaxSystem(owner=(0, 0, 1), volatile=(False, True), n_machines=2)
CFG = cstate.SystemConfig(n_machines=2, owner=(0, 0, 1),
                          volatile=(False, True))


def python_run(actions: np.ndarray):
    """Python mirror of semantics_jax.step (eager flushes)."""
    s = cstate.initial_state(CFG)
    obs = []
    for kind, i, x, v, fl in actions:
        kind, i, x, v = int(kind), int(i), int(x), int(v)
        o = BOT
        if kind == ACT["lstore"]:
            s = step_lstore(CFG, s, i, x, v)
        elif kind == ACT["rstore"]:
            s = step_rstore(CFG, s, i, x, v)
        elif kind == ACT["mstore"]:
            s = step_mstore(CFG, s, i, x, v)
        elif kind == ACT["load"]:
            s, o = step_load(CFG, s, i, x)
        elif kind == ACT["lflush"]:
            if s.C[i][x] is not cstate.BOT:
                if CFG.owner[x] == i:
                    s = step_tau_cm(CFG, s, x)
                else:
                    s = step_tau_cc(CFG, s, i, x)
        elif kind == ACT["rflush"]:
            while s.cached_anywhere(x):
                holders = s.holders(x)
                non_owner = [h for h in holders if h != CFG.owner[x]]
                if non_owner:
                    s = step_tau_cc(CFG, s, non_owner[0], x)
                else:
                    s = step_tau_cm(CFG, s, x)
        elif kind == ACT["tau_cc"]:
            s2 = step_tau_cc(CFG, s, i, x)
            s = s2 if s2 is not None else s
        elif kind == ACT["tau_cm"]:
            s2 = step_tau_cm(CFG, s, x)
            s = s2 if s2 is not None else s
        elif kind == ACT["crash"]:
            s = step_crash(CFG, s, i)
        elif kind == ACT["faa"]:
            (s, o) = step_faa(CFG, s, i, x, v, "l")
        obs.append(o)
    C = np.array([[(BOT if c is cstate.BOT else c) for c in row]
                  for row in s.C], np.int32)
    M = np.array(s.M, np.int32)
    return C, M, np.array(obs, np.int32)


def _assert_equivalent(actions):
    C_j, M_j, obs_j = run_schedule(SYS, jnp.asarray(actions, jnp.int32))
    C_p, M_p, obs_p = python_run(np.asarray(actions))
    np.testing.assert_array_equal(np.asarray(C_j), C_p)
    np.testing.assert_array_equal(np.asarray(M_j), M_p)
    np.testing.assert_array_equal(np.asarray(obs_j), obs_p)


def test_random_schedules_match_reference():
    key = jax.random.PRNGKey(42)
    acts = np.asarray(random_schedules(SYS, key, batch=50, length=40,
                                       p_crash=0.05))
    for b in range(acts.shape[0]):
        _assert_equivalent(acts[b])


@settings(max_examples=60, deadline=None)
@given(st.lists(
    st.tuples(st.integers(0, 10), st.integers(0, 1), st.integers(0, 2),
              st.integers(0, 3), st.just(0)),
    min_size=1, max_size=25))
def test_hypothesis_schedules_match_reference(schedule):
    _assert_equivalent(np.asarray(schedule, np.int32))


def test_vmapped_batch_matches_loop():
    key = jax.random.PRNGKey(7)
    acts = random_schedules(SYS, key, batch=16, length=20)
    Cb, Mb, ob = run_schedules(SYS, acts)
    for b in range(16):
        C1, M1, o1 = run_schedule(SYS, acts[b])
        np.testing.assert_array_equal(np.asarray(Cb[b]), np.asarray(C1))
        np.testing.assert_array_equal(np.asarray(Mb[b]), np.asarray(M1))
        np.testing.assert_array_equal(np.asarray(ob[b]), np.asarray(o1))


def test_invariant_holds_in_jax_runs():
    """Single-valid-value invariant on every step of random JAX schedules."""
    key = jax.random.PRNGKey(3)
    acts = random_schedules(SYS, key, batch=32, length=30)
    C, M, _ = run_schedules(SYS, acts)
    C = np.asarray(C)
    for b in range(C.shape[0]):
        for x in range(SYS.n_locs):
            vals = {v for v in C[b, :, x] if v != BOT}
            assert len(vals) <= 1
