"""The serving subsystem (repro.serve): scheduler contract, tiered KV
spill/restore, durable sessions, and end-to-end engine properties.

Layer by layer:

* scheduler  — pure state machine: admission never exceeds the slot
  count, finished sequences free their slot within one step, FIFO
  fairness under oversubscription;
* kvcache    — slot surgery is exact; spill/restore round-trips
  BIT-identically through the host, peer-staging and pool tiers;
* sessions   — the FliT session commit pairs table + caches atomically;
  async schedules pair the manifest with the meta captured at flush
  LAUNCH (regression: a later table must never describe older caches);
* engine     — continuous batching emits tokens identical to the static
  baseline; in-process kill + resume is bit-identical from both the
  committed-cache and replay paths.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.dsm.pool import DSMPool
from repro.dsm.tiers import TierManager
from repro.serve.kvcache import TieredKVCache
from repro.serve.scheduler import Request, SlotScheduler
from repro.serve.sessions import SessionStore, kv_name
from repro.serve.trace import synthetic_trace, trace_t_max


# ---------------------------------------------------------------------------
# scheduler (no jax)
# ---------------------------------------------------------------------------

def _reqs(n, max_new=4):
    return [Request(f"r{i}", (1, 2, 3), max_new) for i in range(n)]


def test_admission_never_exceeds_slots():
    s = SlotScheduler(3)
    s.submit(_reqs(10))
    placed = s.admit()
    assert len(placed) == 3
    assert s.n_running == 3
    assert s.admit() == []                    # no free slot, no admission
    assert s.n_running == 3


def test_finished_sequence_frees_slot_within_one_step():
    s = SlotScheduler(2)
    s.submit(_reqs(5))
    s.admit()
    slot = s.release("r0")
    assert s.slots[slot] is None
    placed = s.admit()                        # SAME tick refills the lane
    assert [(sl, r.rid) for sl, r in placed] == [(slot, "r2")]


def test_fifo_fairness_under_oversubscription():
    s = SlotScheduler(2)
    s.submit(_reqs(7))
    order = []
    s.admit()
    while not s.done:
        running = list(s.running)
        for rid in running:
            order.append(rid)
            s.release(rid)
        s.admit()
    assert s.admission_order == [f"r{i}" for i in range(7)]
    assert order == [f"r{i}" for i in range(7)]


def test_duplicate_rid_rejected():
    s = SlotScheduler(2)
    s.submit(_reqs(2))
    with pytest.raises(AssertionError):
        s.submit(_reqs(1))


# ---------------------------------------------------------------------------
# shared smoke model
# ---------------------------------------------------------------------------

TRACE_KW = dict(prompt_lens=(16,), new_tokens=(3, 5, 9, 13))


@pytest.fixture(scope="module")
def smoke():
    from repro.configs import get_smoke_config
    from repro.models.registry import build
    cfg = get_smoke_config("olmo-1b")
    trace = synthetic_trace(10, vocab_size=cfg.vocab_size, **TRACE_KW)
    t_max = trace_t_max(trace)
    bundle = build(cfg, dec_pos_len=t_max)
    params = bundle.init_params(jax.random.PRNGKey(0))
    return cfg, bundle, params, trace, t_max


def _engine(smoke, **kw):
    from repro.serve.engine import ServeEngine
    _, bundle, params, _, t_max = smoke
    return ServeEngine(bundle, params, n_slots=4, t_max=t_max, **kw)


@pytest.fixture(scope="module")
def reference_outputs(smoke):
    """Uninterrupted continuous run — the bit-identity oracle."""
    _, _, _, trace, _ = smoke
    return _engine(smoke).run(trace).outputs


def _tree_eq(a, b):
    fa, ta = jax.tree_util.tree_flatten(a)
    fb, tb = jax.tree_util.tree_flatten(b)
    assert ta == tb
    for x, y in zip(fa, fb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# tiered KV cache
# ---------------------------------------------------------------------------

def _filled_cache1(smoke, seed=1):
    """A single-sequence cache with non-trivial contents (via prefill)."""
    _, bundle, params, _, t_max = smoke
    toks = jax.random.randint(jax.random.PRNGKey(seed), (1, 16), 0,
                              smoke[0].vocab_size)
    _, st = bundle.prefill(params, {"tokens": toks},
                           bundle.init_caches(jax.random.PRNGKey(0), 1,
                                              t_max))
    return st.caches


def test_kv_slot_write_read_roundtrip(smoke, tmp_path):
    _, bundle, _, _, t_max = smoke
    kv = TieredKVCache(bundle, 4, t_max)
    c1 = _filled_cache1(smoke)
    kv.write_slot(2, c1)
    _tree_eq(kv.read_slot(2), c1)
    # other lanes untouched (still zeros)
    z = jax.tree_util.tree_leaves(kv.read_slot(0))
    assert all(float(jnp.max(jnp.abs(l.astype(jnp.float32)))) == 0.0
               for l in z)


def test_kv_spill_restore_bit_identical_host_tier(smoke, tmp_path):
    _, bundle, _, _, t_max = smoke
    tiers = TierManager(DSMPool(str(tmp_path / "pool")), worker_id=0)
    kv = TieredKVCache(bundle, 4, t_max, tiers=tiers)
    c1 = _filled_cache1(smoke)
    kv.spill("kv/s0", c1)
    _tree_eq(kv.restore("kv/s0"), c1)


def test_kv_spill_restore_bit_identical_peer_staging(smoke, tmp_path):
    """RStore into a peer's host buffer, then OUR crash: the peer-side
    manager restores the exact bytes from its staging tier."""
    _, bundle, _, _, t_max = smoke
    ours = TierManager(DSMPool(str(tmp_path / "a")), worker_id=0)
    peer = TierManager(DSMPool(str(tmp_path / "b")), worker_id=1)
    kv_ours = TieredKVCache(bundle, 4, t_max, tiers=ours)
    kv_peer = TieredKVCache(bundle, 4, t_max, tiers=peer)
    c1 = _filled_cache1(smoke)
    kv_ours.spill("kv/s0", c1, peer=peer)
    ours.crash()                              # our volatile tiers vanish
    restored = kv_peer.restore("kv/s0")
    assert restored is not None
    _tree_eq(restored, c1)


def test_kv_spill_durable_pool_roundtrip(smoke, tmp_path):
    """Sharded RFlush to the pool (byte-balanced blocks) and back:
    bit-identical including non-native dtypes (bf16 raw-view storage)."""
    _, bundle, _, _, t_max = smoke
    tiers = TierManager(DSMPool(str(tmp_path / "pool")), worker_id=0)
    kv = TieredKVCache(bundle, 4, t_max, tiers=tiers)
    c1 = _filled_cache1(smoke)
    entry = kv.spill_durable("kv/s0", c1, n_blocks=2)
    tiers.crash()                             # host tier gone: pool only
    restored = kv.restore("kv/s0", entry)
    _tree_eq(restored, c1)


def test_kv_block_layout_covers_all_leaves_byte_balanced(smoke):
    _, bundle, _, _, t_max = smoke
    kv = TieredKVCache(bundle, 4, t_max)
    leaves = jax.tree_util.tree_leaves(kv.template1)
    layout = kv.block_layout(2)
    flat = sorted(i for g in layout for i in g)
    assert flat == list(range(len(leaves)))   # exact cover, no dupes
    assert all(g for g in layout)             # no empty block


# ---------------------------------------------------------------------------
# durable sessions
# ---------------------------------------------------------------------------

def test_session_commit_and_recover(smoke, tmp_path):
    from repro.serve.sessions import Session
    _, bundle, _, _, t_max = smoke
    store = SessionStore(DSMPool(str(tmp_path / "pool")))
    kv = TieredKVCache(bundle, 4, t_max, tiers=store.tiers)
    c1 = _filled_cache1(smoke)
    s = Session("r0", (1, 2, 3), 8, emitted=[7, 9])
    store.stage(s, c1)
    store.commit({"r0": s}, step=4)
    store.close()

    store2 = SessionStore(DSMPool(str(tmp_path / "pool")))
    rec = store2.recover(kv.template1)
    assert rec is not None and rec.step == 4
    assert rec.sessions["r0"].emitted == [7, 9]
    assert rec.sessions["r0"].pos == 3 + 2 - 1
    _tree_eq(rec.caches["r0"], c1)


def test_session_recover_falls_back_on_torn_commit(smoke, tmp_path):
    """Corrupting the newest commit's cache file must push recovery to the
    previous manifest — a session table can never pair with torn bytes."""
    import os
    from repro.serve.sessions import Session
    _, bundle, _, _, t_max = smoke
    pool = DSMPool(str(tmp_path / "pool"))
    store = SessionStore(pool)
    kv = TieredKVCache(bundle, 4, t_max, tiers=store.tiers)
    s = Session("r0", (1, 2, 3), 8, emitted=[7])
    store.stage(s, _filled_cache1(smoke, seed=1))
    store.commit({"r0": s}, step=2)
    s.emitted.append(8)
    store.stage(s, _filled_cache1(smoke, seed=2))
    store.commit({"r0": s}, step=4)
    store.close()
    # tear the newest commit: clobber its cache object payload
    obj_dir = os.path.join(str(tmp_path / "pool"), "objects", kv_name("r0"))
    newest = sorted(f for f in os.listdir(obj_dir)
                    if f.endswith((".npz", ".cxl0")))[-1]
    with open(os.path.join(obj_dir, newest), "wb") as f:
        f.write(b"torn")
    rec = SessionStore(DSMPool(str(tmp_path / "pool"))).recover(
        kv.template1)
    assert rec is not None and rec.step == 2
    assert rec.sessions["r0"].emitted == [7]


def test_async_commit_meta_captured_at_launch(tmp_path):
    """Regression: in async schedules the manifest for step s must carry
    the meta passed WITH step s's commit call (captured at flush launch),
    not whatever meta a later commit happens to pass at join time."""
    from repro.dsm.flit_runtime import DurableCommitter
    tiers = TierManager(DSMPool(str(tmp_path / "pool")), worker_id=0)
    c = DurableCommitter(tiers, mode="async")
    c.update({"x": {"a": np.arange(4)}}, step=0)
    assert c.commit(0, meta={"tag": "step0"}) is None   # launched, no join
    c.update({"x": {"a": np.arange(4) + 1}}, step=1)
    st = c.commit(1, meta={"tag": "step1"})             # joins step 0
    assert st is not None and st.step == 0
    c.drain()
    manifests = {m["step"]: m for m in tiers.pool.manifests_desc()}
    assert manifests[0]["meta"] == {"tag": "step0"}
    assert manifests[1]["meta"] == {"tag": "step1"}


# ---------------------------------------------------------------------------
# engine end-to-end
# ---------------------------------------------------------------------------

def test_continuous_matches_static_bitwise(smoke, reference_outputs):
    _, _, _, trace, _ = smoke
    res_s = _engine(smoke).run_static(trace)
    assert res_s.outputs == reference_outputs
    # and the occupancy win is real: strictly fewer decode ticks
    res_c = _engine(smoke).run(trace)
    assert res_c.decode_ticks < res_s.decode_ticks


def test_engine_reuses_freed_slots(smoke):
    _, _, _, trace, _ = smoke
    eng = _engine(smoke)
    res = eng.run(trace)
    # 10 requests through 4 slots: every request got a lane eventually
    assert len(res.outputs) == len(trace)
    assert eng.sched.done
    assert res.prefills == len(trace)
    for r in trace:
        assert len(res.outputs[r.rid]) == r.max_new_tokens


class _Kill(Exception):
    pass


@pytest.mark.parametrize("point,restore_mode", [
    ("pre_flush", "cache"), ("mid_flush", "cache"),
    ("post_completeOp", "cache"), ("mid_flush", "replay"),
])
def test_engine_kill_resume_bit_identical(smoke, reference_outputs,
                                          tmp_path, point, restore_mode):
    """In-process kill inside the session-commit window, then a fresh
    engine resumes from the pool: every session's final tokens equal the
    uninterrupted run exactly — via committed-cache restore AND replay."""
    _, _, _, trace, _ = smoke

    def hook(p, step):
        if p == point and step >= 6:
            raise _Kill()

    store = SessionStore(DSMPool(str(tmp_path / "pool")), fault_hook=hook)
    eng = _engine(smoke, store=store, commit_every=3)
    with pytest.raises(_Kill):
        eng.run(trace)

    store2 = SessionStore(DSMPool(str(tmp_path / "pool")))
    eng2 = _engine(smoke, store=store2, commit_every=3,
                   restore_mode=restore_mode)
    resumed = eng2.resume()
    assert resumed is not None
    done_at_resume = len(eng2.results)
    res = eng2.run(trace)
    assert res.outputs == reference_outputs
    if restore_mode == "cache":
        # fast-forward really happened: recovered-done sessions came back
        # as results and resumed sessions re-entered WITHOUT a prefill
        assert res.prefills == (len(trace) - done_at_resume
                                - res.resumed_sessions)


def test_engine_retire_done_bounds_committed_table(smoke,
                                                   reference_outputs,
                                                   tmp_path):
    """With retire_done, finished sessions leave the committed table one
    commit after completion: the final manifest stays O(live sessions)
    while the caller still gets every output."""
    pool_dir = str(tmp_path / "pool")
    store = SessionStore(DSMPool(pool_dir))
    eng = _engine(smoke, store=store, commit_every=3, retire_done=True)
    _, _, _, trace, _ = smoke
    res = eng.run(trace)
    eng.close()
    assert res.outputs == reference_outputs       # delivery unaffected
    final = DSMPool(pool_dir).latest_manifest()
    assert len(final["meta"]["sessions"]) < len(trace)
    # a restart serves the trace as NEW work for retired sessions only —
    # nothing unfinished was lost
    store2 = SessionStore(DSMPool(pool_dir))
    eng2 = _engine(smoke, store=store2)
    eng2.resume()
    assert all(not s.done or rid in eng2.results
               for rid, s in eng2.sessions.items())


def test_engine_rejects_encoder_decoder(smoke):
    """Encoder-decoder archs fail fast with a clear error, not deep in
    the slot-decode assert (and the CLIs exclude them via
    servable_archs)."""
    from repro.configs import get_smoke_config
    from repro.models.registry import build
    from repro.serve.engine import ServeEngine, servable_archs
    assert "whisper-small" not in servable_archs()
    assert "olmo-1b" in servable_archs()
    cfg = get_smoke_config("whisper-small")
    bundle = build(cfg, dec_pos_len=8)
    with pytest.raises(ValueError, match="decoder-only"):
        ServeEngine(bundle, params=None, n_slots=2, t_max=8)


def test_engine_full_recovery_no_recompute(smoke, reference_outputs,
                                           tmp_path):
    """Restarting over a COMPLETED run's pool returns every output from
    the session table without a single prefill or decode tick."""
    _, _, _, trace, _ = smoke
    store = SessionStore(DSMPool(str(tmp_path / "pool")))
    eng = _engine(smoke, store=store, commit_every=3)
    eng.run(trace)
    eng.close()
    store2 = SessionStore(DSMPool(str(tmp_path / "pool")))
    eng2 = _engine(smoke, store=store2)
    assert eng2.resume() is not None
    res = eng2.run(trace)
    assert res.outputs == reference_outputs
    assert res.prefills == 0 and res.decode_ticks == 0
