"""System-scale serve-worker kill scenarios (repro.scenarios.serve_worker):
a REAL serving process is os._exit-killed inside the session-commit window,
restarted, and must resume from the newest completed session commit and
finish the trace with every session's output tokens bit-identical to an
uninterrupted reference run."""
import pytest

from repro.dsm.flit_runtime import KILL_POINTS
from repro.scenarios.runner import run_serve_scenario, serve_reference

REQUESTS = 10
SLOTS = 4
COMMIT_EVERY = 3


@pytest.fixture(scope="module")
def ref_outputs(tmp_path_factory):
    """One uninterrupted serving run shared by all kill points."""
    return serve_reference(str(tmp_path_factory.mktemp("serve_ref")),
                           requests=REQUESTS, slots=SLOTS,
                           commit_every=COMMIT_EVERY)


@pytest.mark.parametrize("point", KILL_POINTS)
def test_serve_kill_point_replays_bit_identical(point, tmp_path,
                                                ref_outputs):
    res = run_serve_scenario(point, str(tmp_path), requests=REQUESTS,
                             slots=SLOTS, commit_every=COMMIT_EVERY,
                             ref_outputs=ref_outputs)
    assert res.killed, res.detail
    # recovery landed on a COMPLETED session commit — the newest one
    assert res.recovered_completed_commit, res
    assert res.resumed_from == max(res.completed_ticks_at_kill), res
    # the whole point: kill + restart emits the SAME tokens per session
    assert res.outputs_match, res
    assert res.ok


def test_serve_replay_restore_mode(tmp_path, ref_outputs):
    """The prompt-replay restore path (no cache restore) reproduces the
    same outputs — the deterministic-recompute fallback."""
    res = run_serve_scenario("post_completeOp", str(tmp_path),
                             requests=REQUESTS, slots=SLOTS,
                             commit_every=COMMIT_EVERY,
                             restore_mode="replay",
                             ref_outputs=ref_outputs)
    assert res.killed and res.ok, res
