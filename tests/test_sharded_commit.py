"""Sharded + sharded-async commit schedules: partition properties, shard
round-trips, schedule equivalence, retention GC, and commit-window fault
hooks (the in-process complement of the process-kill scenario suite)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.data.pipeline import DataPipeline, SyntheticLMSource
from repro.dsm.flit_runtime import COMMIT_MODES, DurableCommitter
from repro.dsm.pool import DSMPool, partition_leaves
from repro.dsm.recovery import CrashError, RecoveryManager
from repro.dsm.tiers import TierManager
from repro.scenarios.worker import make_toy_state, make_toy_step, state_digest
from repro.train.loop import run_durable_loop


def _pipeline():
    return DataPipeline(SyntheticLMSource(1024), 4, 32)


# -- partition_leaves ---------------------------------------------------------

def test_partition_covers_every_leaf_once():
    sizes = [7, 1, 100, 42, 3, 3, 58, 9]
    groups = partition_leaves(sizes, 3)
    flat = sorted(i for g in groups for i in g)
    assert flat == list(range(len(sizes)))
    assert all(g for g in groups)


def test_partition_balances_bytes():
    sizes = [100] * 8
    groups = partition_leaves(sizes, 4)
    loads = [sum(sizes[i] for i in g) for g in groups]
    assert max(loads) == min(loads) == 200


def test_partition_clamps_to_leaf_count():
    groups = partition_leaves([5, 5], 16)
    assert len(groups) == 2


# -- sharded write / read round-trip -----------------------------------------

def test_sharded_roundtrip_mixed_dtypes(tmp_path):
    pool = DSMPool(str(tmp_path / "p"))
    tiers = TierManager(pool, worker_id=0)
    tree = {"a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
            "b": jnp.ones((5,), jnp.bfloat16),
            "c": {"d": jnp.arange(7, dtype=jnp.int32)}}
    tiers.lstore("obj", tree)
    obj = tiers.rflush_sharded("obj", 2)
    assert len(obj.shards) == 2
    seq = pool.commit_manifest(0, {"obj": obj})
    entry = pool.latest_manifest()["objects"]["obj"]
    assert entry["sharded"] and entry["nbytes"] == obj.nbytes
    back = pool.read_entry("obj", entry, tree)
    for orig, got in zip(jax.tree_util.tree_leaves(tree),
                         jax.tree_util.tree_leaves(back)):
        assert orig.dtype == got.dtype
        assert np.array_equal(np.asarray(orig, np.float32),
                              np.asarray(got, np.float32))


# -- schedule equivalence -----------------------------------------------------

@pytest.mark.parametrize("mode", COMMIT_MODES)
def test_all_schedules_same_durable_history(mode, tmp_path):
    """Every schedule must commit the same final step and produce the same
    final state (the schedules trade latency, never correctness)."""
    pool = DSMPool(str(tmp_path / mode))
    r = run_durable_loop(make_toy_step(), make_toy_state(), _pipeline(),
                         pool, n_steps=8, commit_every=2, commit_mode=mode,
                         n_shards=4)
    assert pool.latest_manifest()["step"] == 7      # drain flushed the tail
    r_ref = run_durable_loop(make_toy_step(), make_toy_state(), _pipeline(),
                             DSMPool(str(tmp_path / f"{mode}_ref")),
                             n_steps=8, commit_every=2, commit_mode="sync")
    assert state_digest(r.state) == state_digest(r_ref.state)


def test_sharded_async_crash_recovery_identical(tmp_path):
    r_clean = run_durable_loop(make_toy_step(), make_toy_state(),
                               _pipeline(), DSMPool(str(tmp_path / "clean")),
                               n_steps=8, commit_every=2, n_shards=4)
    r_crashy = run_durable_loop(
        make_toy_step(), make_toy_state(), _pipeline(),
        DSMPool(str(tmp_path / "crashy")), n_steps=8, commit_every=2,
        n_shards=4, crash_at={3: "before_commit", 5: "after_commit"})
    assert r_crashy.crashes == 2
    assert state_digest(r_clean.state) == state_digest(r_crashy.state)


def test_resume_skips_initial_commit(tmp_path):
    """A restarted worker (resume=True) recovers instead of re-committing a
    fresh step -1 manifest that would shadow newer commits."""
    pool = DSMPool(str(tmp_path / "p"))
    run_durable_loop(make_toy_step(), make_toy_state(), _pipeline(), pool,
                     n_steps=4, commit_every=2, n_shards=2)
    assert pool.latest_manifest()["step"] == 3
    r = run_durable_loop(make_toy_step(), make_toy_state(), _pipeline(),
                         pool, n_steps=8, commit_every=2, n_shards=2,
                         resume=True)
    assert r.resumed_from == 3
    assert r.recoveries == ["pool"]
    assert pool.latest_manifest()["step"] == 7
    r_ref = run_durable_loop(make_toy_step(), make_toy_state(), _pipeline(),
                             DSMPool(str(tmp_path / "ref")), n_steps=8,
                             commit_every=2)
    assert state_digest(r.state) == state_digest(r_ref.state)


# -- retention GC -------------------------------------------------------------

def test_retention_bounds_manifests_and_versions(tmp_path):
    pool = DSMPool(str(tmp_path / "p"))
    run_durable_loop(make_toy_step(), make_toy_state(), _pipeline(), pool,
                     n_steps=12, commit_every=2, n_shards=4, retention=3)
    ms = pool.manifests_desc()
    assert len(ms) == 3
    # every retained manifest still fully recovers
    state = make_toy_state()
    templates = {"params": state.params, "opt_mu": state.opt.mu,
                 "opt_nu": state.opt.nu,
                 "counters": {"opt_step": state.opt.step, "rng": state.rng},
                 "pipeline": {"seed": np.int64(0), "step": np.int64(0)}}
    objs, rec_step, src = RecoveryManager(pool).recover(templates)
    assert rec_step == 11
    # no orphaned shard versions survive GC
    import os
    live = set()
    for m in ms:
        for n, o in m["objects"].items():
            if o.get("sharded"):
                live.update((s["name"], s["version"]) for s in o["shards"])
            else:
                live.add((n, o["version"]))
    for name in os.listdir(pool.obj_dir):
        for fn in os.listdir(os.path.join(pool.obj_dir, name)):
            stem = fn.split(".")[0]
            if stem.isdigit():
                assert (name, int(stem)) in live


# -- commit-window fault hooks ------------------------------------------------

@pytest.mark.parametrize("point", ["pre_flush", "mid_flush"])
def test_fault_hook_before_completeop_leaves_no_manifest(point, tmp_path):
    """A crash at pre-flush or mid-flush (some shards durable) must leave
    the manifest history untouched — the torn write is invisible."""
    pool = DSMPool(str(tmp_path / "p"))
    tiers = TierManager(pool, worker_id=0)

    def hook(p, step):
        if p == point and step >= 0:
            raise CrashError(f"injected at {p}")

    committer = DurableCommitter(tiers, mode="sharded", n_shards=2,
                                 fault_hook=hook)
    committer.update({"obj": {"a": jnp.arange(8.0)}})
    with pytest.raises(CrashError):
        committer.commit(0)
    assert pool.latest_manifest() is None


def test_fault_hook_post_completeop_commit_survives(tmp_path):
    pool = DSMPool(str(tmp_path / "p"))
    tiers = TierManager(pool, worker_id=0)

    def hook(p, step):
        if p == "post_completeOp":
            raise CrashError("injected after completeOp")

    committer = DurableCommitter(tiers, mode="sharded", n_shards=2,
                                 fault_hook=hook)
    committer.update({"obj": {"a": jnp.arange(8.0)}})
    with pytest.raises(CrashError):
        committer.commit(0)
    assert pool.latest_manifest()["step"] == 0      # the rename won
