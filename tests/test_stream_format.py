"""Golden tests for the streamed on-disk format (dsm/stream.py) and its
integration with the pool: round-trips across every dtype the system
stages (including bfloat16, which numpy's buffer protocol refuses), 0-d
and empty leaves, nested namespaces; CRC equivalence with the legacy
``_crc_of_arrays`` definition (the property that makes manifests
format-agnostic); backward compat with legacy ``.npz`` pool objects and
legacy staging spills; frame self-validation against every torn mode; and
the spill-arena reuse contract."""
import os
import struct
import zlib

import numpy as np
import pytest

import jax

from repro.dsm import stream
from repro.dsm.cluster import FileStagingArea
from repro.dsm.faults import TORN_MODES, corrupt_file
from repro.dsm.pool import CorruptObjectError, DSMPool, _crc_of_arrays
from repro.dsm.recovery import RecoveryManager
from repro.dsm.tiers import TierManager

try:
    import ml_dtypes                              # noqa: F401
    HAVE_BF16 = True
except ImportError:                               # pragma: no cover
    HAVE_BF16 = False


def _golden_leaves():
    """One leaf per dtype/shape class the tiers actually move."""
    rng = np.random.default_rng(0)
    leaves = [
        rng.standard_normal((4, 5)).astype(np.float32),
        rng.standard_normal((2, 3, 2)).astype(np.float16),
        rng.integers(-1000, 1000, (7,)).astype(np.int32),
        rng.integers(0, 255, (3, 3)).astype(np.uint8),
        np.array([True, False, True]),
        np.int64(42) + np.zeros((), np.int64),    # 0-d
        np.zeros((0, 8), np.float32),             # empty
        np.asarray(3.5, np.float64),              # 0-d float
    ]
    if HAVE_BF16:
        import ml_dtypes
        leaves.append(rng.standard_normal((4, 4))
                      .astype(ml_dtypes.bfloat16))
    return leaves


def _assert_leaves_equal(got, want):
    assert len(got) == len(want)
    for g, w in zip(got, want):
        assert g.dtype == w.dtype, (g.dtype, w.dtype)
        assert g.shape == w.shape, (g.shape, w.shape)
        np.testing.assert_array_equal(np.asarray(g, np.float64)
                                      if g.dtype.kind not in "biu" else g,
                                      np.asarray(w, np.float64)
                                      if w.dtype.kind not in "biu" else w)


def _frame_path(tmp_path, leaves, arena=None):
    path = str(tmp_path / ("f" + stream.SUFFIX))
    with open(path, "wb") as f:
        crc, total, header = stream.write_frame(f, leaves, arena)
    return path, crc, total, header


# -- frame round-trip ---------------------------------------------------------

def test_frame_round_trip_all_dtypes(tmp_path):
    leaves = _golden_leaves()
    path, crc, total, _ = _frame_path(tmp_path, leaves)
    got, rcrc, header = stream.read_frame(path, expected_crc=crc)
    assert rcrc == crc
    assert header["n"] == len(leaves)
    _assert_leaves_equal(got, leaves)


def test_frame_crc_equals_legacy_definition(tmp_path):
    """The frame CRC is the fold of every leaf's raw bytes in order — the
    SAME value ``_crc_of_arrays`` computes, so a manifest written against
    one format validates objects stored in the other."""
    leaves = _golden_leaves()
    _, crc, _, _ = _frame_path(tmp_path, leaves)
    assert crc == _crc_of_arrays(leaves)


def test_frame_payload_is_tight_concatenation(tmp_path):
    """No padding between leaves: the file size follows exactly from the
    header (the size equation torn-write readers rely on), and the
    payload byte count write_frame reports is the plain leaf sum."""
    leaves = _golden_leaves()
    path, _, total, header = _frame_path(tmp_path, leaves)
    assert total == sum(header["nbytes"]) == sum(
        np.asarray(a).nbytes for a in leaves)
    hdr2, off, size = stream.read_header(path)
    assert hdr2 == header
    assert os.path.getsize(path) == size == off + total + stream._FOOTER_LEN


def test_frame_zero_leaves(tmp_path):
    path, crc, _, _ = _frame_path(tmp_path, [])
    got, rcrc, header = stream.read_frame(path)
    assert got == [] and rcrc == crc == 0 and header["n"] == 0


def test_read_is_zero_copy_views(tmp_path):
    """Reads come back as mmap-backed views (np.frombuffer), not copies —
    each non-trivial leaf's buffer must be rooted in a mmap object."""
    import mmap as _mmap
    leaves = [np.arange(1 << 16, dtype=np.float32),
              np.arange(100, dtype=np.int64)]
    path, crc, _, _ = _frame_path(tmp_path, leaves)
    got, _, _ = stream.read_frame(path, expected_crc=crc)
    for g in got:
        root = g
        while isinstance(root, np.ndarray) and root.base is not None:
            root = root.base
        if isinstance(root, memoryview):         # np.frombuffer wraps one
            root = root.obj
        assert isinstance(root, _mmap.mmap)


# -- torn frames --------------------------------------------------------------

@pytest.mark.parametrize("mode", TORN_MODES)
def test_frame_detects_every_torn_mode(tmp_path, mode):
    leaves = [np.arange(4096, dtype=np.float32)]
    path, crc, _, _ = _frame_path(tmp_path, leaves)
    corrupt_file(path, mode)
    with pytest.raises(stream.FrameError):
        stream.read_frame(path, expected_crc=crc)


def test_frame_rejects_header_damage(tmp_path):
    leaves = [np.arange(64, dtype=np.float32)]
    path, _, _, _ = _frame_path(tmp_path, leaves)
    with open(path, "r+b") as f:
        f.seek(stream._HDR_FIXED + 2)            # inside the header JSON
        b = f.read(1)
        f.seek(stream._HDR_FIXED + 2)
        f.write(bytes([b[0] ^ 0xFF]))
    with pytest.raises(stream.FrameError):
        stream.read_header(path)


def test_frame_rejects_bad_magic(tmp_path):
    path = str(tmp_path / ("f" + stream.SUFFIX))
    with open(path, "wb") as f:
        f.write(b"NOTAFRME" + b"\x00" * 64)
    with pytest.raises(stream.FrameError):
        stream.read_frame(path)


def test_frame_rejects_footer_truncation(tmp_path):
    """Losing only the footer (payload intact) must still be torn."""
    leaves = [np.arange(4096, dtype=np.float32)]
    path, _, _, _ = _frame_path(tmp_path, leaves)
    size = os.path.getsize(path)
    with open(path, "r+b") as f:
        f.truncate(size - stream._FOOTER_LEN + 3)
    with pytest.raises(stream.FrameError):
        stream.read_frame(path)


def test_frame_rejects_swapped_footer_crc(tmp_path):
    """A forged footer CRC fails against expected_crc from the manifest."""
    leaves = [np.arange(1024, dtype=np.float32)]
    path, crc, total, _ = _frame_path(tmp_path, leaves)
    with open(path, "r+b") as f:
        f.seek(total - stream._FOOTER_LEN + 8)
        f.write(struct.pack("<I", (crc ^ 0xFFFFFFFF) & 0xFFFFFFFF))
    with pytest.raises(stream.FrameError):
        stream.read_frame(path, expected_crc=crc)


# -- pool integration ---------------------------------------------------------

def test_pool_round_trip_nested_namespaces(tmp_path):
    pool = DSMPool(str(tmp_path))
    tree = {"w0/params": {"layer": {"w": np.ones((3, 3), np.float32),
                                    "b": np.zeros(3, np.float32)}},
            "scalars": {"step": np.int64(7)}}
    obj = pool.write_object("ns/deep/t", 3, tree)
    got = pool.read_object("ns/deep/t", 3, tree, expected_crc=obj.crc)
    np.testing.assert_array_equal(got["w0/params"]["layer"]["w"],
                                  tree["w0/params"]["layer"]["w"])
    assert int(got["scalars"]["step"]) == 7


def test_pool_crc_identical_across_formats(tmp_path):
    """write_object and write_object_legacy yield the SAME PoolObject crc
    for the same tree — manifests don't care which format wrote it."""
    pool = DSMPool(str(tmp_path))
    tree = {"a": np.arange(100, dtype=np.float32),
            "b": {"c": np.asarray(1.5, np.float64)}}
    new = pool.write_object("x", 1, tree)
    old = pool.write_object_legacy("y", 1, tree)
    assert new.crc == old.crc
    assert new.nbytes == old.nbytes


def test_pool_reads_legacy_npz_objects(tmp_path):
    """Backward compat: objects written by the PR-6 pool (np.savez +
    sidecar) still read, CRC-validate, and recover."""
    pool = DSMPool(str(tmp_path))
    tree = {"a": np.arange(50, dtype=np.float32)}
    obj = pool.write_object_legacy("t", 1, tree)
    assert os.path.basename(pool.payload_path("t", 1)).endswith(".npz")
    got = pool.read_object("t", 1, tree, expected_crc=obj.crc)
    np.testing.assert_array_equal(got["a"], tree["a"])
    pool.commit_manifest(0, {"t": obj})
    objs, step, src = RecoveryManager(pool).recover({"t": tree})
    assert (step, src) == (0, "pool")
    np.testing.assert_array_equal(objs["t"]["a"], tree["a"])


def test_mixed_format_manifest_recovers(tmp_path):
    """One manifest referencing a legacy object AND a streamed object —
    the mid-upgrade state — must validate and recover whole."""
    pool = DSMPool(str(tmp_path))
    t1 = {"a": np.arange(10, dtype=np.float32)}
    t2 = {"b": np.arange(20, dtype=np.int32)}
    o1 = pool.write_object_legacy("old", 1, t1)
    o2 = pool.write_object("new", 1, t2)
    pool.commit_manifest(0, {"old": o1, "new": o2})
    objs, step, src = RecoveryManager(pool).recover({"old": t1, "new": t2})
    assert (step, src) == (0, "pool")
    np.testing.assert_array_equal(objs["old"]["a"], t1["a"])
    np.testing.assert_array_equal(objs["new"]["b"], t2["b"])


@pytest.mark.skipif(not HAVE_BF16, reason="ml_dtypes unavailable")
def test_pool_round_trip_bfloat16(tmp_path):
    import ml_dtypes
    pool = DSMPool(str(tmp_path))
    tree = {"w": np.arange(32).reshape(4, 8).astype(ml_dtypes.bfloat16)}
    obj = pool.write_object("bf", 1, tree)
    got = pool.read_object("bf", 1, tree, expected_crc=obj.crc)
    assert got["w"].dtype == tree["w"].dtype
    np.testing.assert_array_equal(np.asarray(got["w"], np.float32),
                                  np.asarray(tree["w"], np.float32))


# -- staging backward compat --------------------------------------------------

def test_staging_reads_legacy_spills(tmp_path):
    """A staging area populated by the PR-6 writer (``.npz`` + dtype/shape
    meta) is still readable by today's ``view``."""
    tree = {"w": np.full((4, 4), 2.5, np.float32)}
    legacy = FileStagingArea(str(tmp_path / "s"), legacy_format=True)
    legacy.proxy(1).staging["w0/t"] = (9, tree)
    # a fresh, default-format handle on the same root reads it
    area = FileStagingArea(str(tmp_path / "s"))
    view = area.view(1, {"w0/t": tree})
    tag, got = view.staging["w0/t"]
    assert tag == 9
    np.testing.assert_array_equal(got["w"], tree["w"])


def test_staging_streams_and_rstore_defers_d2h(tmp_path):
    """The streamed spill path end-to-end through rstore, and satellite 6:
    a spill-file peer advertises ``materializes_leaves`` so rstore hands
    the tree over without an eager whole-tree host copy."""
    pool = DSMPool(str(tmp_path / "p"))
    area = FileStagingArea(str(tmp_path / "s"))
    proxy = area.proxy(0)
    assert getattr(proxy.staging, "materializes_leaves", False)
    tiers = TierManager(pool, worker_id=1)
    tree = {"w": np.arange(64, dtype=np.float32)}
    tiers.lstore("w1/t", tree)
    tiers.rstore("w1/t", proxy, tag=4)
    assert area.payload_path(0, "w1/t").endswith(stream.SUFFIX)
    view = area.view(0, {"w1/t": tree})
    tag, got = view.staging["w1/t"]
    assert tag == 4
    np.testing.assert_array_equal(got["w"], tree["w"])
    # in-process dict peers do NOT advertise it: rstore still snapshots
    peer = TierManager(pool, worker_id=2)
    assert not getattr(peer.staging, "materializes_leaves", False)


# -- arena --------------------------------------------------------------------

def test_arena_reuses_buffer_across_writes(tmp_path):
    arena = stream.SpillArena()
    leaves = [np.full((64,), i, np.float32) for i in range(32)]
    for i in range(5):
        path = str(tmp_path / f"f{i}{stream.SUFFIX}")
        with open(path, "wb") as f:
            stream.write_frame(f, leaves, arena)
    assert arena.allocations == 1        # one grow, then steady-state reuse
    got, _, _ = stream.read_frame(str(tmp_path / f"f4{stream.SUFFIX}"))
    _assert_leaves_equal(got, leaves)


def test_arena_grows_geometrically():
    arena = stream.SpillArena()
    arena.checkout(10)
    arena.checkout(arena.MIN_BYTES * 3)
    assert arena.allocations == 2
    mv = arena.checkout(arena.MIN_BYTES * 2)   # fits in the grown buffer
    assert arena.allocations == 2
    assert len(mv) >= arena.MIN_BYTES * 2
