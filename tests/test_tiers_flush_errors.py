"""Regression tests for failed background flushes: the exception must
surface at the join (flush_wait), and the FliT counter must return to 0
either way — the original bug stored only successes, so a failed threaded
write raised a bare KeyError from flush_wait and leaked the raised
counter forever (every later joiner would think the pool copy is
permanently stale)."""
import jax.numpy as jnp
import pytest

from repro.dsm.flit_runtime import DurableCommitter
from repro.dsm.pool import DSMPool
from repro.dsm.tiers import TierManager


class BoomError(OSError):
    pass


@pytest.fixture
def tiers(tmp_path):
    t = TierManager(DSMPool(str(tmp_path)), worker_id=0)
    yield t
    t.close()


def _fail_writes(tiers, monkeypatch):
    def boom(name, version, tree):
        raise BoomError(f"disk full writing {name}@{version}")
    monkeypatch.setattr(tiers.pool, "write_object", boom)


def test_failed_threaded_flush_surfaces_and_counter_drops(tiers,
                                                          monkeypatch):
    tiers.lstore("x", {"a": jnp.arange(8.0)})
    _fail_writes(tiers, monkeypatch)
    tiers.flush_async("x")
    with pytest.raises(BoomError):
        tiers.flush_wait("x")
    assert tiers.flit_counter["x"] == 0
    # the error was consumed: a later successful flush works normally
    monkeypatch.undo()
    tiers.lstore("x", {"a": jnp.arange(8.0)})
    tiers.flush_async("x")
    obj = tiers.flush_wait("x")
    assert obj.name == "x" and tiers.flit_counter["x"] == 0


def test_failed_threaded_flush_abort_drops_counter(tiers, monkeypatch):
    tiers.lstore("x", {"a": jnp.arange(8.0)})
    _fail_writes(tiers, monkeypatch)
    tiers.flush_async("x")
    tiers.abort_flushes()           # crash path: join-and-discard
    assert tiers.flit_counter["x"] == 0
    assert not tiers._flush_errors and not tiers._flush_results


def test_failed_sharded_flush_surfaces_and_counter_drops(tiers,
                                                         monkeypatch):
    tiers.lstore("x", {"a": jnp.arange(8.0), "b": jnp.arange(4.0)})
    _fail_writes(tiers, monkeypatch)
    tiers.flush_async_sharded("x", n_shards=2)
    with pytest.raises(BoomError):
        tiers.flush_wait("x")
    assert tiers.flit_counter["x"] == 0


def test_async_commit_surfaces_failed_flush_without_manifest(tmp_path,
                                                             monkeypatch):
    """A commit whose background write failed is simply NOT durable: the
    join raises, no manifest is written, and the committer stays usable."""
    pool = DSMPool(str(tmp_path))
    tiers = TierManager(pool, worker_id=0)
    committer = DurableCommitter(tiers, mode="async")
    committer.update({"x": {"a": jnp.arange(8.0)}})
    committer.commit(0)                       # launches background flush
    _fail_writes(tiers, monkeypatch)
    # the step-0 flush may already hold the unpatched callable mid-write;
    # discard it and launch a fresh flush that is guaranteed to fail
    committer.abort_pending()
    committer.update({"x": {"a": jnp.arange(8.0)}})
    committer.commit(1)
    with pytest.raises(BoomError):
        committer.commit(2)                   # joins step 1's failed flush
    assert tiers.flit_counter["x"] == 0
    assert pool.latest_manifest() is None     # nothing ever completed
    tiers.close()
